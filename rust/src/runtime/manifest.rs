//! The AOT artifact manifest (`artifacts/manifest.json`).
//!
//! Produced by `python/compile/aot.py` alongside the `.hlo.txt` files; the
//! Rust side type-checks kernel invocations against it at *load* time so a
//! shape mismatch is a clear `Error::Runtime` up front, not an XLA failure
//! deep inside a benchmark run.

use std::path::{Path, PathBuf};

use crate::config::Json;
use crate::error::{Error, Result};

/// One tensor signature (dtype is always f32 in this system).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    /// Argument name (inputs only; outputs are positional).
    pub name: String,
    /// Dimensions (row-major).
    pub dims: Vec<usize>,
}

impl TensorSig {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (e.g. `fwd_accum_t1200`).
    pub name: String,
    /// HLO text file (relative to the artifacts dir).
    pub file: String,
    /// Input signatures in call order.
    pub inputs: Vec<TensorSig>,
    /// Output signatures in tuple order.
    pub outputs: Vec<TensorSig>,
    /// FLOPs per invocation (from the Python cost annotation).
    pub flops: u64,
    /// Benchmark phase tag ("feed_forward", "combine_gradients", …).
    pub phase: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Hidden-layer width the artifacts were built for.
    pub hidden: usize,
    /// All artifacts.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        Self::from_json(dir, &j)
    }

    /// Parse from a JSON document.
    pub fn from_json(dir: PathBuf, j: &Json) -> Result<Manifest> {
        let hidden = j.req_usize("hidden")?;
        let arts = j
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Config("'artifacts' must be an array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a.req_str("name")?.to_string();
            let file = a.req_str("file")?.to_string();
            let sig = |v: &Json, positional: bool| -> Result<Vec<TensorSig>> {
                v.as_arr()
                    .ok_or_else(|| Error::Config(format!("{name}: signature must be array")))?
                    .iter()
                    .enumerate()
                    .map(|(i, t)| {
                        let dims = t
                            .req("dims")?
                            .as_arr()
                            .ok_or_else(|| Error::Config(format!("{name}: dims must be array")))?
                            .iter()
                            .map(|d| {
                                d.as_usize().ok_or_else(|| {
                                    Error::Config(format!("{name}: dims must be integers"))
                                })
                            })
                            .collect::<Result<Vec<_>>>()?;
                        let nm = if positional {
                            format!("out{i}")
                        } else {
                            t.req_str("name")?.to_string()
                        };
                        Ok(TensorSig { name: nm, dims })
                    })
                    .collect()
            };
            let inputs = sig(a.req("inputs")?, false)?;
            let outputs = sig(a.req("outputs")?, true)?;
            let meta = a.req("meta")?;
            let flops = meta.get("flops").and_then(Json::as_u64).unwrap_or(0);
            let phase = meta.get("phase").and_then(Json::as_str).unwrap_or("unknown").to_string();
            artifacts.push(ArtifactSpec { name, file, inputs, outputs, flops, phase });
        }
        Ok(Manifest { dir, hidden, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Artifact names matching a prefix (e.g. all `fwd_accum_t*`).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<&str> {
        self.artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix))
            .map(|a| a.name.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "hidden": 100, "tb": 75,
      "artifacts": [
        {"name": "fwd_shard_t225", "file": "fwd_shard_t225.hlo.txt",
         "sha256": "x",
         "inputs": [{"name": "w", "dtype": "f32", "dims": [100, 225]},
                    {"name": "x", "dtype": "f32", "dims": [225]}],
         "outputs": [{"dtype": "f32", "dims": [100]}],
         "meta": {"phase": "feed_forward", "flops": 45000}}
      ]}"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::from_json(PathBuf::from("arts"), &Json::parse(DOC).unwrap()).unwrap();
        assert_eq!(m.hidden, 100);
        let a = m.get("fwd_shard_t225").unwrap();
        assert_eq!(a.inputs[0].dims, vec![100, 225]);
        assert_eq!(a.inputs[0].elems(), 22500);
        assert_eq!(a.outputs[0].dims, vec![100]);
        assert_eq!(a.flops, 45000);
        assert_eq!(a.phase, "feed_forward");
        assert_eq!(m.path_of(a), PathBuf::from("arts/fwd_shard_t225.hlo.txt"));
        assert!(m.get("nope").is_err());
        assert_eq!(m.names_with_prefix("fwd_").len(), 1);
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration-lite: if `make artifacts` has run, validate it.
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let m = Manifest::load("artifacts").unwrap();
            assert_eq!(m.hidden, 100);
            assert!(m.get("head_h100").is_ok());
            assert!(!m.names_with_prefix("fwd_accum_t").is_empty());
        }
    }
}
