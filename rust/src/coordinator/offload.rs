//! Kernel registry and offload options/results.
//!
//! Mirrors the ePython `@offload` decorator surface: a kernel is compiled
//! once ([`Kernel`]), then invoked many times with different arguments and
//! [`OffloadOptions`] ("numerous options that the programmer can pass to
//! the offload directive ... such as running on a subset of cores").

use std::rc::Rc;

use crate::error::{Error, Result};
use crate::sim::Time;
use crate::vm::{self, CostCounters, Program, TierChoice, Value};

use super::engine::{LaunchCheckpoint, LaunchId};
use super::prefetch::PrefetchSpec;
use super::TransferMode;

/// A compiled kernel ready for offload.
///
/// Cloning is two reference-count bumps (`Rc`-backed name and program), so
/// kernels pass by value freely — the registry, the launch builder and the
/// engine's launch table all hold their own handle to one shared program.
#[derive(Debug, Clone)]
pub struct Kernel {
    name: Rc<str>,
    /// Compiled program (shared across invocations).
    pub program: Rc<Program>,
}

impl Kernel {
    /// Compile kernel source; `entry` selects the `def` (default: last).
    pub fn compile(name: impl Into<String>, src: &str, entry: Option<&str>) -> Result<Kernel> {
        let program = Rc::new(vm::compile_source(src, entry)?);
        Ok(Kernel { name: Rc::from(name.into()), program })
    }

    /// Wrap an already-compiled program (e.g. the fusion differential
    /// tests, which compile fused and unfused variants directly).
    pub fn from_program(name: impl Into<String>, program: Rc<Program>) -> Kernel {
        Kernel { name: Rc::from(name.into()), program }
    }

    /// Registry name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Bytecode footprint (the part of the local store user code occupies).
    pub fn code_bytes(&self) -> usize {
        self.program.functions.iter().map(|f| f.code_bytes()).sum()
    }
}

/// Named kernel store (one per session).
#[derive(Debug, Default)]
pub struct KernelRegistry {
    kernels: Vec<Kernel>,
}

impl KernelRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Compile + register. Re-registering a name replaces it. The stored
    /// and returned kernels share one `Rc`-backed program — no deep copy.
    pub fn register(&mut self, name: &str, src: &str, entry: Option<&str>) -> Result<Kernel> {
        let k = Kernel::compile(name, src, entry)?;
        if let Some(slot) = self.kernels.iter_mut().find(|e| e.name() == name) {
            *slot = k.clone();
        } else {
            self.kernels.push(k.clone());
        }
        Ok(k)
    }

    /// Look up by name (borrow; clone the result only if you need to keep
    /// it across a mutable session call — the clone is two `Rc` bumps).
    pub fn get(&self, name: &str) -> Result<&Kernel> {
        self.kernels
            .iter()
            .find(|k| k.name() == name)
            .ok_or_else(|| Error::Coordinator(format!("unknown kernel '{name}'")))
    }

    /// Registered kernel count.
    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    /// Whether no kernels are registered.
    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }
}

/// Options for one offload invocation.
#[derive(Debug, Clone)]
pub struct OffloadOptions {
    /// Argument transfer mode.
    pub mode: TransferMode,
    /// Physical cores to run on (`None` = all).
    pub cores: Option<Vec<usize>>,
    /// Default pre-fetch annotation for reference args without their own.
    pub default_prefetch: Option<PrefetchSpec>,
    /// Dispatch budget per core (runaway guard).
    pub fuel: u64,
    /// Explicit launch-graph dependency edges: this launch activates only
    /// after every named launch has completed (`LaunchBuilder::after`).
    /// Edges may only point at already-submitted launches — a forward or
    /// self edge is rejected at submit time (cycle rejection).
    pub after: Vec<LaunchId>,
    /// Infer data-flow dependency edges from the launch's argument
    /// read/write sets (on by default). Disabling stops *this* launch
    /// waiting on inferred edges — it is unordered, not invisible: later
    /// launches still infer edges against its flow set, and `quiesce`
    /// still drains it. Overlap with earlier in-flight mutable data then
    /// gets §3.3's weak cross-launch memory model
    /// (`LaunchBuilder::independent`).
    pub flow_deps: bool,
    /// Earliest virtual time the launch may activate, regardless of core
    /// availability (default 0 = no floor). This is how an *external*
    /// dependency enters the graph: the multi-device group charges its
    /// host-level staging copies on the service timelines and passes the
    /// copy's completion time here, so a cross-device dependent launch
    /// activates no earlier than the staged data's arrival — exactly like
    /// an in-engine edge raising `dep_ready`.
    pub not_before: Time,
    /// Transient-fault retry budget (default 0 = today's fail-fast: the
    /// first fault abandons the launch and poisons its dependents). With a
    /// budget, a faulted launch restores its last checkpoint and requeues
    /// on the same device, consuming one retry per fault.
    pub retry: u32,
    /// Virtual-time back-off inserted before each retry requeue (on top of
    /// the modeled checkpoint-restore cost). Default 0.
    pub backoff: Time,
    /// Owning tenant, for fleet-level multiplexing (`None` = untagged, the
    /// default for direct session use). Pure metadata: the tag is stored
    /// on the launch record and surfaced through per-tenant accounting
    /// ([`crate::coordinator::Engine::queue_stats_for_tenant`]) but never
    /// consulted by scheduling — admission control upstream decides *when*
    /// a launch is submitted, the engine stays tenant-blind about *what*
    /// runs (engine invariant 11).
    pub tenant: Option<u64>,
    /// Execution tier for the per-core VMs: the fused interpreter
    /// (default), the compiled direct-dispatch tier, or `Auto` (the engine
    /// compiles once the kernel's launch repeats or its dispatch volume
    /// crosses the hot threshold). Tier choice never changes values,
    /// counters or suspension points — it changes host overhead and the
    /// modelled code-image footprint (`code_bytes` of the lowered image
    /// when compiled).
    pub tier: TierChoice,
    /// Resume from a harvested checkpoint instead of starting fresh — set
    /// by the multi-device group when it migrates a launch off a lost
    /// device; never by user code.
    pub(crate) restore: Option<Rc<LaunchCheckpoint>>,
}

impl Default for OffloadOptions {
    fn default() -> Self {
        OffloadOptions {
            mode: TransferMode::OnDemand,
            cores: None,
            default_prefetch: None,
            fuel: 2_000_000_000,
            after: Vec::new(),
            flow_deps: true,
            not_before: 0,
            retry: 0,
            backoff: 0,
            tenant: None,
            tier: TierChoice::Interp,
            restore: None,
        }
    }
}

impl OffloadOptions {
    /// Set the transfer mode.
    pub fn transfer(mut self, mode: TransferMode) -> Self {
        self.mode = mode;
        self
    }

    /// Restrict to a core subset.
    pub fn on_cores(mut self, cores: Vec<usize>) -> Self {
        self.cores = Some(cores);
        self
    }

    /// Set the default pre-fetch annotation (switches mode to Prefetch).
    pub fn prefetch(mut self, spec: PrefetchSpec) -> Self {
        self.mode = TransferMode::Prefetch;
        self.default_prefetch = Some(spec);
        self
    }

    /// Set the per-core dispatch budget (runaway guard).
    pub fn fuel(mut self, fuel: u64) -> Self {
        self.fuel = fuel;
        self
    }

    /// Add an explicit dependency edge: don't activate before `dep`
    /// completes.
    pub fn after(mut self, dep: LaunchId) -> Self {
        self.after.push(dep);
        self
    }

    /// Opt out of inferred data-flow dependency edges for this launch.
    pub fn independent(mut self) -> Self {
        self.flow_deps = false;
        self
    }

    /// Floor the activation time (external-dependency edge — see the
    /// field docs on [`OffloadOptions::not_before`]).
    pub fn not_before(mut self, at: Time) -> Self {
        self.not_before = at;
        self
    }

    /// Set the transient-fault retry budget (see
    /// [`OffloadOptions::retry`]; 0 = fail-fast, the default).
    pub fn retry(mut self, n: u32) -> Self {
        self.retry = n;
        self
    }

    /// Set the virtual-time back-off before each retry requeue.
    pub fn backoff(mut self, t: Time) -> Self {
        self.backoff = t;
        self
    }

    /// Tag the launch with its owning tenant (see
    /// [`OffloadOptions::tenant`]; fleet bookkeeping only, never
    /// scheduling).
    pub fn tenant(mut self, tenant: u64) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// Select the execution tier (see [`OffloadOptions::tier`]).
    pub fn tier(mut self, tier: TierChoice) -> Self {
        self.tier = tier;
        self
    }
}

/// Per-core execution record in an [`OffloadResult`].
#[derive(Debug, Clone)]
pub struct CoreReport {
    /// Physical core id.
    pub core: usize,
    /// Kernel return value.
    pub value: Value,
    /// Core-local finish time.
    pub finished_at: Time,
    /// Virtual time spent stalled on transfers.
    pub stall: Time,
    /// VM cost counters.
    pub counters: CostCounters,
    /// Channel requests issued by this core.
    pub requests: u64,
    /// Peak channel-cell occupancy.
    pub peak_cells: usize,
    /// Times the core found no free cell (backpressure).
    pub cell_stalls: u64,
}

/// Result of a blocking offload across cores.
#[derive(Debug, Clone)]
pub struct OffloadResult {
    /// One report per participating core (in core-id order).
    pub reports: Vec<CoreReport>,
    /// Launch virtual time.
    pub launched_at: Time,
    /// Finish virtual time (max over cores, incl. result copy-back).
    pub finished_at: Time,
    /// Eager-copy arguments that did not fit on-core and were spilled to
    /// by-reference access.
    pub spills: u64,
}

impl OffloadResult {
    /// Per-core return values (paper: "sixteen identical results, one from
    /// each micro-core, are copied back in a list").
    pub fn per_core(&self) -> Vec<&Value> {
        self.reports.iter().map(|r| &r.value).collect()
    }

    /// Wall (virtual) duration of the offload.
    pub fn elapsed(&self) -> Time {
        self.finished_at - self.launched_at
    }

    /// Aggregate stall time across cores.
    pub fn total_stall(&self) -> Time {
        self.reports.iter().map(|r| r.stall).sum()
    }

    /// Aggregate channel requests.
    pub fn total_requests(&self) -> u64 {
        self.reports.iter().map(|r| r.requests).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = "def k(a):\n    return a\n";

    #[test]
    fn registry_register_get_replace() {
        let mut r = KernelRegistry::new();
        r.register("k", SRC, None).unwrap();
        assert_eq!(r.get("k").unwrap().program.arity(), 1);
        assert!(r.get("missing").is_err());
        // replace with a 2-arg kernel
        r.register("k", "def k(a, b):\n    return a\n", None).unwrap();
        assert_eq!(r.get("k").unwrap().program.arity(), 2);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn options_builders() {
        let o = OffloadOptions::default()
            .transfer(TransferMode::Eager)
            .on_cores(vec![0, 2]);
        assert_eq!(o.mode, TransferMode::Eager);
        assert_eq!(o.cores, Some(vec![0, 2]));
        let p = PrefetchSpec {
            buffer_size: 8,
            elems_per_fetch: 4,
            distance: 4,
            access: super::super::Access::ReadOnly,
        };
        let o = OffloadOptions::default().prefetch(p);
        assert_eq!(o.mode, TransferMode::Prefetch);
        assert!(o.default_prefetch.is_some());
        let o = OffloadOptions::default().retry(3).backoff(1_000);
        assert_eq!((o.retry, o.backoff), (3, 1_000));
        let d = OffloadOptions::default();
        assert_eq!((d.retry, d.backoff), (0, 0), "default stays fail-fast");
        assert!(d.restore.is_none());
        assert_eq!(d.tenant, None, "direct session use stays untagged");
        assert_eq!(OffloadOptions::default().tenant(7).tenant, Some(7));
    }

    #[test]
    fn kernel_code_fits_microcore_budget() {
        // The analyzer's per-technology budget check replaces the former
        // ad-hoc byte-count assert (and is what `Session::compile_kernel`
        // now enforces at registration).
        let k = Kernel::compile("k", SRC, None).unwrap();
        let diags = crate::analysis::check_kernel_budget(
            k.name(),
            &k.program,
            &crate::device::Technology::epiphany3(),
        );
        assert!(diags.is_empty(), "{diags:?}");
    }
}
