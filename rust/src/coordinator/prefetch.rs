//! The pre-fetch engine (§3.1).
//!
//! The paper's API:
//! `prefetch={variable name, buffer size, elements per pre-fetch, distance,
//! access modifier}` — [`PrefetchSpec`] carries the numbers,
//! [`PrefetchState`] is the per-(core, argument) runtime state machine.
//!
//! Semantics implemented exactly as described:
//!
//! * `buffer_size` elements are reserved in the core's local store (the
//!   memory cost the paper highlights: "40 bytes are required for each
//!   function argument");
//! * each request moves `elems_per_fetch` elements — "a by product of
//!   pre-fetching is that it retrieves multiple pieces of data on each
//!   access [so] the overall number of data accesses is significantly
//!   lower";
//! * fetch-ahead triggers whenever the stream position is within
//!   `distance` elements of the fetched frontier;
//! * mutable buffers write through (atomic per element, core-ordered).
//!
//! The state machine is *sequential-stream oriented* (the paper's access
//! pattern); a random access outside the buffered window invalidates the
//! window and restarts streaming at the new position — correct, just slow,
//! matching how a real pre-fetcher degrades.

use crate::channel::protocol::CELL_PAYLOAD_ELEMS;
use crate::channel::Handle;
use crate::error::{Error, Result};

use super::Access;

/// The §3.1 pre-fetch annotation for one kernel argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchSpec {
    /// Elements reserved on-core for this argument's buffer.
    pub buffer_size: usize,
    /// Elements moved per request (capped by the 1 KB cell payload).
    pub elems_per_fetch: usize,
    /// Fetch-ahead trigger distance, in elements.
    pub distance: usize,
    /// Read-only vs mutable (write-back) — the access modifier.
    pub access: Access,
}

impl PrefetchSpec {
    /// Validate against protocol and sanity limits.
    pub fn validate(&self) -> Result<()> {
        if self.buffer_size == 0 || self.elems_per_fetch == 0 {
            return Err(Error::Coordinator("prefetch sizes must be positive".into()));
        }
        if self.elems_per_fetch > self.buffer_size {
            return Err(Error::Coordinator(
                "elems_per_fetch cannot exceed buffer_size".into(),
            ));
        }
        if self.elems_per_fetch > CELL_PAYLOAD_ELEMS {
            return Err(Error::Coordinator(format!(
                "elems_per_fetch {} exceeds the 1 KB cell payload ({} elements)",
                self.elems_per_fetch, CELL_PAYLOAD_ELEMS
            )));
        }
        Ok(())
    }

    /// On-core memory this argument's buffer consumes (bytes).
    pub fn buffer_bytes(&self) -> usize {
        self.buffer_size * 4
    }
}

/// An in-flight fetch: `[start, start+len)` arriving via `handle`.
#[derive(Debug, Clone, Copy)]
pub struct Inflight {
    /// Channel handle of the request.
    pub handle: Handle,
    /// First element index covered.
    pub start: usize,
    /// Elements covered.
    pub len: usize,
}

/// What the state machine wants done next for a read at some index.
#[derive(Debug, PartialEq)]
pub enum ReadPlan {
    /// Element available in the buffer right now.
    Hit(f64),
    /// Wait on this in-flight handle (data already requested).
    WaitInflight(Handle),
    /// Buffer/inflight do not cover the index: issue fetches starting at
    /// the given element (the state was re-seeded).
    Miss,
}

/// Per-(core, argument) pre-fetch runtime state.
#[derive(Debug)]
pub struct PrefetchState {
    spec: PrefetchSpec,
    /// Total length of the external view.
    total_len: usize,
    /// Valid window: elements `[lo, hi)` are in `buf`.
    lo: usize,
    hi: usize,
    buf: Vec<f32>,
    /// Requested-but-not-arrived spans (kept in issue order).
    inflight: Vec<Inflight>,
    /// Write-through values for elements covered by an in-flight span:
    /// the span was read at issue time, so its payload is stale for these
    /// elements; the overlay re-applies them on arrival (§3.3: "preference
    /// is given to any local copy").
    overlay: Vec<(usize, f32)>,
    /// Next element index to request.
    next_fetch: usize,
    /// Statistics.
    hits: u64,
    misses: u64,
    fetches_issued: u64,
}

impl PrefetchState {
    /// Fresh state for a view of `total_len` elements.
    pub fn new(spec: PrefetchSpec, total_len: usize) -> Result<Self> {
        spec.validate()?;
        Ok(PrefetchState {
            spec,
            total_len,
            lo: 0,
            hi: 0,
            buf: Vec::with_capacity(spec.buffer_size),
            inflight: Vec::new(),
            overlay: Vec::new(),
            next_fetch: 0,
            hits: 0,
            misses: 0,
            fetches_issued: 0,
        })
    }

    /// The annotation this state was built from.
    pub fn spec(&self) -> &PrefetchSpec {
        &self.spec
    }

    /// (hits, misses, fetches issued).
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.hits, self.misses, self.fetches_issued)
    }

    /// Mutation-free hit probe: the value for `idx` if (and only if) it is
    /// resident in the buffered window right now. Unlike
    /// [`PrefetchState::plan_read`] this does not touch the hit/miss
    /// statistics and never re-seeds the stream — the engine's inline
    /// fast path uses it to decide whether a read can bypass the
    /// scheduler round-trip entirely (pair with
    /// [`PrefetchState::note_hit`] to keep the statistics identical).
    pub fn peek_hit(&self, idx: usize) -> Option<f64> {
        if idx >= self.lo && idx < self.hi {
            Some(f64::from(self.buf[idx - self.lo]))
        } else {
            None
        }
    }

    /// Record a hit taken through [`PrefetchState::peek_hit`], keeping
    /// `stats()` identical to the `plan_read` path.
    pub fn note_hit(&mut self) {
        self.hits += 1;
    }

    /// Mutation-free probe: would [`PrefetchState::spans_to_fetch`] issue
    /// at least one span for a read at `idx`? This *is* that method's loop
    /// condition (it calls this), so the two cannot drift — the engine's
    /// inline fast path is only legal when this is `false` (no
    /// host-service resource would be allocated out of global time
    /// order).
    pub fn wants_fetch(&self, idx: usize) -> bool {
        if self.next_fetch >= self.total_len {
            return false; // stream exhausted
        }
        if self.live_occupancy(idx) >= self.spec.buffer_size {
            return false; // buffer full
        }
        // Only fetch ahead within the trigger distance.
        self.next_fetch <= idx + self.spec.distance
    }

    /// Buffer occupancy if all inflight arrive, counting only the *live*
    /// window `[max(lo, idx), next_fetch)`: elements behind the read
    /// position are dead for a sequential stream and will be evicted on
    /// the next arrival.
    fn live_occupancy(&self, idx: usize) -> usize {
        self.next_fetch.saturating_sub(self.lo.max(idx))
    }

    /// Plan a read of element `idx`.
    pub fn plan_read(&mut self, idx: usize) -> ReadPlan {
        if idx >= self.lo && idx < self.hi {
            self.hits += 1;
            return ReadPlan::Hit(f64::from(self.buf[idx - self.lo]));
        }
        if let Some(f) = self.inflight.iter().find(|f| idx >= f.start && idx < f.start + f.len) {
            // Requested, still in the air: stall on that handle.
            self.misses += 1;
            return ReadPlan::WaitInflight(f.handle);
        }
        // Outside window and not requested: re-seed the stream here.
        self.misses += 1;
        self.lo = idx;
        self.hi = idx;
        self.buf.clear();
        self.next_fetch = idx;
        // In-flight spans for the old stream will be dropped on arrival;
        // overlay values are already in the home location (write-through),
        // so refetching delivers them.
        self.inflight.clear();
        self.overlay.clear();
        ReadPlan::Miss
    }

    /// Spans to request now: called after a read at `idx` (and at kernel
    /// start with `idx = 0`). Issues ahead while (a) the frontier is
    /// within `distance` of `idx`, (b) buffer space remains, (c) data
    /// remains. Returns `(start, len)` spans; caller issues the channel
    /// requests and registers them via [`PrefetchState::on_issued`].
    pub fn spans_to_fetch(&mut self, idx: usize) -> Vec<(usize, usize)> {
        let mut spans = Vec::new();
        // Loop condition shared with the engine's fast-path probe: one
        // predicate, no drift (see `wants_fetch`).
        while self.wants_fetch(idx) {
            let occupied = self.live_occupancy(idx);
            let len = self
                .spec
                .elems_per_fetch
                .min(self.total_len - self.next_fetch)
                .min(self.spec.buffer_size - occupied);
            spans.push((self.next_fetch, len));
            self.next_fetch += len;
        }
        spans
    }

    /// The stream's window start — the earliest element still resident.
    /// Together with [`PrefetchState::seek`] this is how a launch
    /// checkpoint captures and restores a pre-fetch stream: the cursor is
    /// the only position that must survive (buffered data is re-fetched
    /// from the home location on resume, which also re-delivers any
    /// write-through values — they are already home).
    pub fn cursor(&self) -> usize {
        self.lo
    }

    /// Re-seed the stream at `idx` without touching the hit/miss
    /// statistics: checkpoint *restore* repositions the stream exactly
    /// where the snapshot left it, and accounting a miss for that would
    /// make a recovered run's statistics diverge from its fault-free twin
    /// for reasons that are not the kernel's accesses. The mechanical
    /// effect is identical to [`PrefetchState::plan_read`]'s miss arm.
    pub fn seek(&mut self, idx: usize) {
        self.lo = idx;
        self.hi = idx;
        self.buf.clear();
        self.next_fetch = idx;
        self.inflight.clear();
        self.overlay.clear();
    }

    /// Register a channel request covering `[start, start+len)`.
    pub fn on_issued(&mut self, handle: Handle, start: usize, len: usize) {
        self.fetches_issued += 1;
        self.inflight.push(Inflight { handle, start, len });
    }

    /// Outstanding request handles (consumed on arrival).
    pub fn inflight(&self) -> &[Inflight] {
        &self.inflight
    }

    /// Data for `[start, start+len)` arrived; fold into the window.
    /// Stale arrivals (from a superseded stream) are dropped.
    pub fn on_arrival(&mut self, handle: Handle, data: &[f32]) {
        let Some(pos) = self.inflight.iter().position(|f| f.handle == handle) else {
            return; // stale
        };
        let f = self.inflight.remove(pos);
        debug_assert_eq!(f.len, data.len());
        if f.start != self.hi {
            // Out-of-order arrival for a contiguous stream can only happen
            // after a re-seed; drop.
            return;
        }
        // Evict from the front if the window would exceed the buffer.
        let new_size = (self.hi + data.len()).saturating_sub(self.lo);
        if new_size > self.spec.buffer_size {
            let evict = new_size - self.spec.buffer_size;
            self.buf.drain(..evict.min(self.buf.len()));
            self.lo += evict;
        }
        self.buf.extend_from_slice(data);
        self.hi += data.len();
        // Re-apply writes that raced this span (its payload was read at
        // issue time and is stale for them).
        let (lo, hi) = (self.lo, self.hi);
        let buf = &mut self.buf;
        self.overlay.retain(|&(idx, val)| {
            if idx >= lo && idx < hi {
                buf[idx - lo] = val;
                false
            } else {
                true
            }
        });
    }

    /// Write-through of element `idx` (mutable buffers): update the local
    /// copy if resident; if the element is covered by an in-flight span,
    /// remember the value so the (stale) arrival cannot clobber it. The
    /// caller issues the write-back request.
    pub fn on_write(&mut self, idx: usize, value: f32) {
        if idx >= self.lo && idx < self.hi {
            self.buf[idx - self.lo] = value;
        } else if self.inflight.iter().any(|f| idx >= f.start && idx < f.start + f.len) {
            self.overlay.push((idx, value));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn handle(i: usize) -> Handle {
        Handle { cell: i, generation: 0 }
    }

    fn spec() -> PrefetchSpec {
        PrefetchSpec { buffer_size: 10, elems_per_fetch: 2, distance: 10, access: Access::ReadOnly }
    }

    #[test]
    fn validates_against_cell_payload() {
        let bad = PrefetchSpec {
            buffer_size: 1000,
            elems_per_fetch: 300,
            distance: 10,
            access: Access::ReadOnly,
        };
        assert!(bad.validate().is_err(), "300 elems > 256-elem cell");
        assert!(spec().validate().is_ok());
        assert_eq!(spec().buffer_bytes(), 40, "paper: 10 ints = 40 bytes");
    }

    #[test]
    fn initial_fill_respects_buffer_and_distance() {
        let mut st = PrefetchState::new(spec(), 100).unwrap();
        let spans = st.spans_to_fetch(0);
        // buffer 10, fetch 2 ⇒ 5 spans of 2
        assert_eq!(spans, vec![(0, 2), (2, 2), (4, 2), (6, 2), (8, 2)]);
        // nothing further until data is consumed
        assert!(st.spans_to_fetch(0).is_empty());
    }

    #[test]
    fn hit_after_arrival_and_streaming_advance() {
        let mut st = PrefetchState::new(spec(), 100).unwrap();
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        assert_eq!(st.plan_read(0), ReadPlan::WaitInflight(handle(0)));
        st.on_arrival(handle(0), &[10.0, 11.0]);
        assert_eq!(st.plan_read(0), ReadPlan::Hit(10.0));
        assert_eq!(st.plan_read(1), ReadPlan::Hit(11.0));
        // consuming ahead triggers more spans once the window slides
        st.on_arrival(handle(1), &[12.0, 13.0]);
        st.on_arrival(handle(2), &[14.0, 15.0]);
        st.on_arrival(handle(3), &[16.0, 17.0]);
        st.on_arrival(handle(4), &[18.0, 19.0]);
        // window now [0,10): full buffer; reading at 8 triggers lookahead
        // for the live window [8, ...) — elements behind 8 are dead
        let spans = st.spans_to_fetch(8);
        assert_eq!(spans, vec![(10, 2), (12, 2), (14, 2), (16, 2)]);
        for (i, (s, l)) in spans.into_iter().enumerate() {
            st.on_issued(handle(10 + i), s, l);
        }
        st.on_arrival(handle(10), &[20.0, 21.0]);
        // 0..2 evicted
        assert_eq!(st.plan_read(10), ReadPlan::Hit(20.0));
        assert!(matches!(st.plan_read(0), ReadPlan::Miss), "evicted element misses");
    }

    #[test]
    fn random_access_reseeds_stream() {
        let mut st = PrefetchState::new(spec(), 1000).unwrap();
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        assert!(matches!(st.plan_read(500), ReadPlan::Miss));
        let spans = st.spans_to_fetch(500);
        assert_eq!(spans[0], (500, 2));
        let (h, m, _) = st.stats();
        assert_eq!(h, 0);
        assert_eq!(m, 1);
    }

    #[test]
    fn stale_arrivals_dropped_after_reseed() {
        let mut st = PrefetchState::new(spec(), 1000).unwrap();
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        st.plan_read(500); // reseed clears inflight
        st.on_arrival(handle(0), &[1.0, 2.0]); // stale: ignored
        assert!(matches!(st.plan_read(0), ReadPlan::Miss));
    }

    #[test]
    fn tail_of_stream_fetches_partial_span() {
        let mut st = PrefetchState::new(spec(), 5).unwrap();
        let spans = st.spans_to_fetch(0);
        assert_eq!(spans, vec![(0, 2), (2, 2), (4, 1)], "last span truncated");
    }

    #[test]
    fn write_racing_inflight_span_survives_arrival() {
        // Regression: a write to an element covered by an in-flight span
        // must not be clobbered when the (stale) span lands.
        let mut st = PrefetchState::new(
            PrefetchSpec { access: Access::Mutable, ..spec() },
            100,
        )
        .unwrap();
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        st.on_write(0, 42.0); // span (0,2) still in flight
        st.on_arrival(handle(0), &[0.0, 1.0]); // stale payload
        assert_eq!(st.plan_read(0), ReadPlan::Hit(42.0), "overlay wins");
        assert_eq!(st.plan_read(1), ReadPlan::Hit(1.0), "untouched element fresh");
    }

    #[test]
    fn peek_and_wants_fetch_mirror_plan_read() {
        let mut st = PrefetchState::new(spec(), 100).unwrap();
        assert!(st.peek_hit(0).is_none());
        assert!(st.wants_fetch(0), "empty stream wants the initial fill");
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        assert!(!st.wants_fetch(0), "window fully requested: nothing to issue");
        st.on_arrival(handle(0), &[10.0, 11.0]);
        assert_eq!(st.peek_hit(0), Some(10.0));
        assert_eq!(st.peek_hit(2), None, "not yet arrived");
        let (h0, _, _) = st.stats();
        st.note_hit();
        assert_eq!(st.stats().0, h0 + 1);
        // peek_hit agrees with plan_read on residency
        assert_eq!(st.plan_read(0), ReadPlan::Hit(10.0));
    }

    #[test]
    fn seek_repositions_without_miss_accounting() {
        let mut st = PrefetchState::new(spec(), 1000).unwrap();
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        assert_eq!(st.cursor(), 0);
        st.seek(500);
        assert_eq!(st.cursor(), 500);
        let (h, m, _) = st.stats();
        assert_eq!((h, m), (0, 0), "seek is invisible to the statistics");
        // Stream restarts at the seek point, like plan_read's miss arm.
        let spans = st.spans_to_fetch(500);
        assert_eq!(spans[0], (500, 2));
        st.on_arrival(handle(0), &[1.0, 2.0]); // pre-seek arrival: stale
        assert!(st.peek_hit(0).is_none());
    }

    #[test]
    fn write_through_updates_resident_copy() {
        let mut st = PrefetchState::new(
            PrefetchSpec { access: Access::Mutable, ..spec() },
            100,
        )
        .unwrap();
        for (i, (s, l)) in st.spans_to_fetch(0).into_iter().enumerate() {
            st.on_issued(handle(i), s, l);
        }
        st.on_arrival(handle(0), &[1.0, 2.0]);
        st.on_write(1, 42.0);
        assert_eq!(st.plan_read(1), ReadPlan::Hit(42.0));
        st.on_write(50, 9.0); // non-resident: no-op locally
    }
}
