//! The offload engine: a deterministic min-clock discrete-event scheduler
//! with an asynchronous launch queue.
//!
//! Each participating core runs a resumable [`Interp`]; the engine
//! interleaves them with the channel protocol, the host service, the
//! shared link and PJRT tensor execution, all over virtual time.
//!
//! **Launch graph (dependency-driven pipelining).** [`Engine::submit`]
//! enqueues a launch and returns a [`LaunchId`] without advancing time;
//! completion is driven by [`Engine::wait`] / [`Engine::wait_all`] /
//! [`Engine::poll`]. Every submitted launch carries a set of *dependency
//! edges* — explicit (`OffloadOptions::after`, the builder's `.after`)
//! plus edges **inferred from data flow**: the bound arguments' read/write
//! windows ([`super::marshal::BoundArg::flow`]) form the launch's flow
//! set, and any pair of in-flight launches whose windows overlap with at
//! least one writer is ordered by an edge. That subsumes the classic
//! hazard triad — a reader depends on the live writers of its buffer
//! (RAW), and a writer depends on the live readers *and* writers before
//! it (WAR + WAW); redundant edges to earlier writers are harmless
//! because the writers are already transitively ordered among themselves.
//! A launch *activates* (stages code, eager copies, pre-fetch warm-up)
//! only when **all its edges are satisfied and every core it names is
//! free**, at virtual time `max(submit, dependencies' finishes, cores'
//! releases)`. Among ready launches activation order is deterministic
//! (submission order; the work-conserving scan lets a later ready launch
//! start ahead of an earlier one still blocked on a core or an edge).
//! Edges always point at already-submitted launches, so the graph is
//! acyclic by construction; a forward or self edge is rejected at submit
//! time. A failed launch parks its own error and propagates
//! [`Error::DependencyFailed`] to its transitive dependents — each parks
//! its *own* error, and launches with no path to the failure are
//! untouched. A dependent chain submitted with no intervening waits is
//! bit-identical (results, stats, trace) to the same chain run blocking;
//! sequential submit-then-wait is bit-identical to the classic blocking
//! [`Engine::offload`] (which is literally submit + wait);
//! `tests/async_launch.rs` and `tests/launch_graph.rs` enforce all of
//! this. Launches that opt out of flow inference
//! (`OffloadOptions::independent`) and still share *mutable* data see
//! §3.3's weak memory model writ large: element accesses interleave
//! deterministically in virtual-time order, but no cross-launch ordering
//! is promised.
//!
//! **Scheduling discipline (exactness).** Every core has a *candidate
//! time*: its local clock (runnable / produced an outcome), its pending
//! transfer's arrival time (blocked), or its channel's next free-cell time
//! (backpressured). The engine always services the core with the minimum
//! candidate over *all active launches* (ties: submission order, then core
//! position). Cores interact *only* through the host service and link
//! resources, and every resource allocation happens at the picked core's
//! candidate time — a non-decreasing sequence — so FCFS resource order
//! equals virtual-time order and the simulation is exact, not approximate.
//! Two bounded exceptions soften the non-decreasing property without
//! breaking determinism (resources serialize FCFS in call order, like a
//! real bus — see `sim/timeline.rs`): teardown copy-backs are issued at
//! each core's own finish time, and a queued launch activates at the
//! freed cores' release times (or its dependencies' finish times, for a
//! launch gated by graph edges), both of which may sit slightly behind
//! the global cursor when other launches are still in flight.
//!
//! **Numerics are real.** Element reads return the variable's actual
//! contents from the [`MemRegistry`]; writes land in it; tensor builtins
//! execute the AOT-compiled JAX/Pallas artifacts through PJRT. The same
//! run that produces the paper's timing figures trains the actual model.
//!
//! **Prefetch-hit fast path (when inline resume is legal).** Each VM
//! outcome normally costs a scheduler round trip: requeue the core,
//! re-find the global minimum, re-dispatch. When an external read
//! resolves entirely from an already-arrived pre-fetch buffer *and*
//! topping up the stream would issue no new request
//! ([`PrefetchState::wants_fetch`] is false), servicing it touches no
//! shared resource: the buffer hit is core-local, consuming already-landed
//! responses is core-local (channels are per-core), and the VM advance
//! moves only this core's clock. Such reads commute with every other
//! core's events, so the engine resumes the VM inline and keeps going —
//! bit-identical virtual times, stalls and trace; strictly less
//! wall-clock. The moment an iteration would allocate a shared resource
//! (issue a pre-fetch span, start an on-demand transfer, read
//! core-local registry state, or finish the kernel) the engine hands the
//! outcome back to the scheduler so host-service allocations stay in
//! global time order — the FCFS-equals-virtual-time exactness invariant
//! above. [`Engine::set_fast_path`] disables the inline path (the
//! differential tests compare both).
//!
//! **Cache-aware transfer costing.** Element-request service costs are no
//! longer fixed at a variable's home level: before servicing a read/write
//! the engine probes [`MemRegistry::access_level`] for the exact range,
//! so a range resident in a [`crate::memory::SharedCacheKind`] is charged
//! at `Shared` (no host staging) while a miss is charged at the backing
//! level — and the probe happens *before* the data access, because the
//! access itself refills the cache. Numerics are unaffected: the cache is
//! coherent by construction (write-back on evict, host-side flush/patch),
//! so cached and uncached runs produce bit-identical values and differ
//! only in virtual time.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, HashSet};
use std::rc::Rc;

use crate::analysis::{
    AccessRecord, Diagnostic, GraphReport, InferredWindow, KernelSummary, LaunchFlowReport,
    Severity, VerifyLevel,
};
use crate::channel::protocol::{Request, RequestKind, FRAME_HEADER_BYTES};
use crate::channel::{Channel, Handle};
use crate::device::{ComputeModel, PowerModel, Scratchpad, Technology};
use crate::error::{Error, Result};
use crate::memory::{DataRef, Level, MemRegistry};
use crate::runtime::ModelExecutor;
use crate::sim::{CacheCounters, FaultCounters, FaultPlan, Rng, Time, Trace};
use crate::vm::{
    lower_program, Builtin, CostCounters, Interp, LinearProgram, Outcome, TensorOp, TierChoice,
    Value, VmSnapshot,
};

use super::marshal::BoundArg;
use super::offload::{CoreReport, Kernel, OffloadOptions, OffloadResult};
use super::prefetch::{PrefetchState, ReadPlan};
use super::service::HostService;
use super::Access;

/// Aggregate engine statistics (monotonic across offloads).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Offloads executed.
    pub offloads: u64,
    /// Channel requests serviced.
    pub requests: u64,
    /// Bytes moved by tensor-builtin DMA.
    pub dma_bytes: u64,
    /// Bytes moved by eager argument copies.
    pub eager_bytes: u64,
    /// Eager arguments spilled to by-reference (didn't fit on-core).
    pub spills: u64,
    /// Tensor builtins executed natively because no PJRT executor was
    /// attached (pure-VM sessions).
    pub native_fallbacks: u64,
    /// Total PJRT tensor-builtin executions.
    pub tensor_ops: u64,
}

/// Outcome summary of one engine-level offload (see also
/// [`OffloadResult`], which the offload layer assembles from this).
pub type OffloadOutcome = OffloadResult;

/// Identifier of a submitted launch, returned by [`Engine::submit`] and
/// redeemed by [`Engine::wait`]. Wrapped by the session layer's
/// `OffloadHandle`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LaunchId(pub(crate) u64);

impl LaunchId {
    /// The raw engine-assigned id (for tooling/persistence).
    pub fn raw(self) -> u64 {
        self.0
    }

    /// Rebuild an id from its raw value. The engine validates ids at
    /// submit time — a dependency edge naming a launch that was never
    /// submitted (or has not been submitted *yet*) is rejected as a
    /// cycle, so a fabricated id cannot corrupt the graph.
    pub fn from_raw(raw: u64) -> LaunchId {
        LaunchId(raw)
    }
}

/// Lifecycle stage of a submitted launch ([`Engine::launch_status`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchStatus {
    /// Waiting on dependency edges: at least one launch it depends on
    /// (explicit `.after` or inferred data flow) has not completed. The
    /// launch holds no cores while blocked.
    Blocked,
    /// Dependencies satisfied but not yet staged onto its cores: queued
    /// behind launches occupying one of them, or simply not driven yet
    /// (nothing runs until a `wait`/`wait_all`/`poll` drives the
    /// timeline).
    Pending,
    /// Staged on its cores and progressing on the virtual timeline.
    Active,
    /// Finished; the result is parked until `wait` claims it.
    Completed,
}

/// Snapshot of the launch table by lifecycle stage
/// ([`Engine::queue_stats`]) — distinguishes launches blocked on
/// dependency edges from launches queued on core contention, so a caller
/// staring at an idle device can tell *why* nothing is running.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Launches waiting on unsatisfied dependency edges.
    pub blocked: usize,
    /// Launches with satisfied edges queued on busy cores (or not yet
    /// driven).
    pub pending: usize,
    /// Launches progressing on the virtual timeline.
    pub active: usize,
    /// Launches finished with the outcome parked for `wait`.
    pub completed: usize,
}

impl QueueStats {
    /// Field-wise accumulate of another snapshot — how a multi-device
    /// [`crate::coordinator::GroupSession`] and the fleet layer aggregate
    /// per-engine breakdowns into one pool-wide view (same idiom as
    /// `CacheCounters::merge`).
    pub fn merge(&mut self, other: &QueueStats) {
        self.blocked += other.blocked;
        self.pending += other.pending;
        self.active += other.active;
        self.completed += other.completed;
    }
}

/// Per-tier execution accounting ([`Engine::tier_counters`]) — how much
/// work ran on the interpreter vs the compiled linear-IR tier (see
/// [`crate::vm::tier`]), plus the tier selector's decisions. Kept out of
/// [`EngineStats`] deliberately: tier choice never changes numerics, and
/// the differential suites pin `EngineStats` bit-for-bit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierCounters {
    /// Launch activations that ran on the interpreter tier.
    pub interp_launches: u64,
    /// Launch activations that ran on the compiled tier.
    pub compiled_launches: u64,
    /// Bytecode dispatches retired by interpreter-tier launches.
    pub interp_dispatches: u64,
    /// Bytecode-equivalent dispatches retired by compiled-tier launches
    /// (the compiled tier charges the same weights, so the two dispatch
    /// columns are directly comparable).
    pub compiled_dispatches: u64,
    /// Distinct kernel programs lowered to the linear IR (each is lowered
    /// once and cached by program identity).
    pub lowered_kernels: u64,
    /// `Auto` launches the heuristic promoted to the compiled tier.
    pub auto_promotions: u64,
    /// Compiled-tier requests demoted back to the interpreter because the
    /// lowered image would not fit the core's local store.
    pub budget_demotions: u64,
}

impl TierCounters {
    /// Field-wise accumulate of another snapshot — how the multi-device
    /// [`crate::coordinator::GroupSession`] aggregates per-engine tier
    /// breakdowns (same idiom as [`QueueStats::merge`]).
    pub fn merge(&mut self, other: &TierCounters) {
        self.interp_launches += other.interp_launches;
        self.compiled_launches += other.compiled_launches;
        self.interp_dispatches += other.interp_dispatches;
        self.compiled_dispatches += other.compiled_dispatches;
        self.lowered_kernels += other.lowered_kernels;
        self.auto_promotions += other.auto_promotions;
        self.budget_demotions += other.budget_demotions;
    }
}

/// Per-program launch/dispatch history driving [`TierChoice::Auto`]
/// promotion (keyed by program identity, like the summary cache).
#[derive(Debug, Clone, Copy, Default)]
struct TierHeat {
    /// Times this program was submitted.
    launches: u64,
    /// Dispatches retired by completed launches of this program.
    dispatches: u64,
}

/// `Auto` compiles a kernel once it is submitted this many times (a
/// repeated launch amortizes the one-time lowering).
const AUTO_COMPILE_LAUNCHES: u64 = 2;

/// `Auto` also compiles a kernel whose completed launches have already
/// retired this many dispatches (a single hot kernel earns the tier
/// without repetition).
const AUTO_COMPILE_DISPATCHES: u64 = 50_000;

/// Event-heap sentinel in the core-position slot: the event activates the
/// launch (stages it onto its now-free cores) instead of stepping a core.
const EV_ACTIVATE: usize = usize::MAX;

/// Refresh a core's checkpoint every this-many scheduler-visible
/// suspensions (plus always at core completion). A per-suspension
/// checkpoint would dominate the service timeline for chatty kernels; a
/// sparse cadence bounds replay to at most this many suspensions while
/// keeping the Shared-level write traffic modest. The first suspension
/// always checkpoints, so even a fault arriving immediately after launch
/// finds something better than a from-scratch restart.
const CHECKPOINT_EVERY: u64 = 8;

/// A resumable snapshot of one launch, taken at suspension points of its
/// cores (see the "life of a fault" walkthrough in ARCHITECTURE.md).
///
/// Each participating core contributes a VM snapshot (stack, locals,
/// program counter, pending suspension), its eager-copy write-back roots,
/// and its pre-fetch stream cursors. Checkpoints are charged as
/// Shared-level writes when taken and Shared-level reads when restored —
/// recovery is cost-modeled, never free. The multi-device group stages a
/// harvested checkpoint through Host level when it migrates a launch off a
/// lost device ([`Engine::harvest_checkpoint`]).
#[derive(Debug, Clone)]
pub struct LaunchCheckpoint {
    /// Per core-position entry; `None` means that core has not reached a
    /// checkpointable suspension yet (restore restarts it from its bound
    /// arguments — deterministic, just more replay).
    cores: Vec<Option<CoreCheckpoint>>,
    /// Total serialized footprint (sum over cores).
    bytes: u64,
}

impl LaunchCheckpoint {
    /// Serialized footprint in bytes — what every checkpoint write,
    /// restore read and migration staging copy is charged for.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

/// One core's share of a [`LaunchCheckpoint`].
#[derive(Debug, Clone)]
struct CoreCheckpoint {
    /// Interpreter state (stack, frames, locals, pending suspension).
    vm: VmSnapshot,
    /// Indices into the snapshot's array table for each eager-copy
    /// write-back root, in `eager_writebacks` order — restore re-links the
    /// write-back list to the rebuilt arrays so copy-back at completion
    /// sees the replayed values.
    wb_roots: Vec<usize>,
    /// Where execution resumes.
    resume: ResumePoint,
    /// Accumulated transfer-stall time at snapshot.
    stall: Time,
    /// `(bind slot, stream cursor)` for every pre-fetch stream; restore
    /// re-seeds each stream at its cursor ([`PrefetchState::seek`]).
    pf_cursors: Vec<(usize, usize)>,
    /// This core's serialized footprint.
    bytes: u64,
}

/// The suspension a checkpointed core resumes from.
#[derive(Debug, Clone)]
enum ResumePoint {
    /// Suspended asking for element `index` of reference slot `slot`.
    Read { slot: usize, index: usize },
    /// Suspended writing `value` to element `index` of slot `slot`.
    Write { slot: usize, index: usize, value: f64 },
    /// Core already finished; restore parks the (deep-copied) result and
    /// marks the core done without re-running anything.
    Done { result: Option<Value> },
}

/// One entry of a launch's data-flow set: the hull of every window the
/// launch's bound arguments open onto one registry variable, and whether
/// any of them may write there. One span per distinct variable — per-core
/// shard windows of the same variable collapse into their covering range
/// (conservative: interleaved disjoint windows may report a spurious
/// overlap, which only ever *adds* a deterministic edge, never loses one).
#[derive(Debug, Clone, Copy)]
struct FlowSpan {
    /// Registry variable id (`DataRef::id` — stable, never recycled).
    id: u64,
    /// First element touched (base-view relative).
    start: usize,
    /// One past the last element touched.
    end: usize,
    /// Whether any argument opens the variable mutably.
    write: bool,
}

impl FlowSpan {
    /// The span as a view, so every aliasing question funnels through the
    /// one canonical predicate ([`DataRef::overlaps`]).
    fn as_view(&self) -> DataRef {
        DataRef { id: self.id, offset: self.start, len: self.end - self.start }
    }

    /// Whether two flow sets must be ordered: aliasing views with at
    /// least one writer (RAW / WAR / WAW — read-read pairs commute and
    /// stay unordered).
    fn conflicts(&self, other: &FlowSpan) -> bool {
        (self.write || other.write) && self.as_view().overlaps(&other.as_view())
    }

    /// Whether this span can alias the given view (any access kind).
    fn touches(&self, dref: &DataRef) -> bool {
        self.as_view().overlaps(dref)
    }
}

/// Collapse a launch's bound arguments into its data-flow set.
fn collect_flows(bound: &[Vec<BoundArg>]) -> Vec<FlowSpan> {
    let mut flows: Vec<FlowSpan> = Vec::new();
    for (dref, access) in bound.iter().flatten().filter_map(BoundArg::flow) {
        let write = access == Access::Mutable;
        match flows.iter_mut().find(|f| f.id == dref.id) {
            Some(f) => {
                f.start = f.start.min(dref.offset);
                f.end = f.end.max(dref.offset + dref.len);
                f.write |= write;
            }
            None => flows.push(FlowSpan {
                id: dref.id,
                start: dref.offset,
                end: dref.offset + dref.len,
                write,
            }),
        }
    }
    flows
}

/// Precise record of one externally visible argument binding — the
/// unmerged counterpart of [`FlowSpan`]. `collect_flows` collapses shard
/// windows into whole-buffer hulls for the scheduler; the static verifier
/// instead needs the exact per-core view each VM slot was bound to, so it
/// can diff inferred windows against *real* declarations rather than
/// hulls. Collected at submit, kept for the launch's lifetime (`bound` is
/// consumed at activation).
#[derive(Debug, Clone, Copy)]
struct ExtArgDecl {
    /// Position in the launch's argument vector == the kernel parameter
    /// index == the VM external slot.
    param: u16,
    /// The exact view bound on this core.
    dref: DataRef,
    access: Access,
    /// `true` for an eager copy-in (whole-view read at activation, plus a
    /// whole-view write-back when mutable), `false` for by-reference.
    eager: bool,
    /// Whether the binding carries a prefetch annotation.
    prefetched: bool,
    /// The variable's home level at submit time.
    level: Level,
}

/// Collect the precise per-core external argument declarations (see
/// [`ExtArgDecl`]); outer index = core position, matching `bound`.
fn collect_ext_args(bound: &[Vec<BoundArg>], registry: &MemRegistry) -> Vec<Vec<ExtArgDecl>> {
    bound
        .iter()
        .map(|args| {
            args.iter()
                .enumerate()
                .filter_map(|(p, a)| {
                    let (dref, access) = a.flow()?;
                    let (eager, prefetched) = match a {
                        BoundArg::EagerCopy { .. } => (true, false),
                        BoundArg::External { prefetch, .. } => (false, prefetch.is_some()),
                        _ => return None,
                    };
                    let level = registry.info(dref).map(|i| i.level).unwrap_or(Level::Host);
                    Some(ExtArgDecl { param: p as u16, dref, access, eager, prefetched, level })
                })
                .collect()
        })
        .collect()
}

/// Map a kernel summary through one launch's precise argument
/// declarations into base-buffer [`InferredWindow`]s — the analyzer's view
/// of the launch's flow set. Summary intervals are view-relative; each is
/// clamped to its core's bound view (sound: the VM bounds-checks before
/// any external access is performed, so an out-of-view index never becomes
/// an access) and shifted by the view offset. Eager copies contribute
/// their definite whole-view transfers (copy-in read, mutable copy-back
/// write) — and those windows also cover the spill path, where the
/// argument falls back to by-reference element access inside the view.
fn inferred_windows(summary: &KernelSummary, ext_args: &[Vec<ExtArgDecl>]) -> Vec<InferredWindow> {
    let mut out = Vec::new();
    for d in ext_args.iter().flatten() {
        let buf = d.dref.id;
        if d.eager {
            out.push(InferredWindow {
                buf,
                lo: d.dref.offset,
                hi: d.dref.offset + d.dref.len,
                write: false,
                approx: false,
            });
            if d.access == Access::Mutable {
                out.push(InferredWindow {
                    buf,
                    lo: d.dref.offset,
                    hi: d.dref.offset + d.dref.len,
                    write: true,
                    approx: false,
                });
            }
            continue;
        }
        let arg = summary.args.get(d.param as usize).cloned().unwrap_or(crate::analysis::ArgSummary {
            read: Some((crate::analysis::Interval::top(), true)),
            write: Some((crate::analysis::Interval::top(), true)),
        });
        for (win, write) in [(arg.read, false), (arg.write, true)] {
            if let Some((iv, approx)) = win {
                if let Some((lo, hi)) = iv.clamp_window(d.dref.len) {
                    out.push(InferredWindow {
                        buf,
                        lo: d.dref.offset + lo,
                        hi: d.dref.offset + hi,
                        write,
                        approx,
                    });
                }
            }
        }
    }
    out
}

/// The scheduler's hull flow set rendered as conflict windows, so hull
/// and inferred flows answer aliasing questions through one predicate
/// ([`InferredWindow::conflicts`], which matches [`FlowSpan::conflicts`]).
fn hull_windows(flows: &[FlowSpan]) -> Vec<InferredWindow> {
    flows
        .iter()
        .map(|f| InferredWindow { buf: f.id, lo: f.start, hi: f.end, write: f.write, approx: true })
        .collect()
}

/// Minimum inferred on-demand read width (elements) before the verifier
/// flags a host-level binding with no prefetch annotation as streaming.
const STREAM_LINT_MIN: usize = 16;

/// Per-launch flow lints over the precise declarations: under-declared
/// flows (the bytecode may write through an argument bound read-only) and
/// memory-kind capability (a kernel streaming a `Host`-level kind
/// element-by-element with prefetch disabled). Findings are deduplicated
/// per parameter — every core runs the same kernel, so one finding per
/// argument carries the full signal.
fn lint_flows(
    summary: &KernelSummary,
    ext_args: &[Vec<ExtArgDecl>],
    launch: Option<u64>,
    kernel: &str,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut flagged_write: HashSet<u16> = HashSet::new();
    let mut flagged_stream: HashSet<u16> = HashSet::new();
    for d in ext_args.iter().flatten() {
        let Some(arg) = summary.args.get(d.param as usize) else { continue };
        if d.access == Access::ReadOnly {
            if let Some((iv, approx)) = arg.write {
                if flagged_write.insert(d.param) {
                    let p = d.param;
                    let win = iv
                        .clamp_window(d.dref.len)
                        .map_or_else(|| iv.to_string(), |(lo, hi)| format!("[{lo}, {hi})"));
                    let (severity, message) = if d.eager {
                        // The writes land in the on-core copy and are
                        // discarded at completion — legal, likely a bug.
                        (
                            Severity::Warning,
                            format!(
                                "writes {win} of read-only arg {p}, but the argument is an \
                                 eager copy — the writes are silently discarded"
                            ),
                        )
                    } else if approx {
                        // Imprecise windows never reject: the lattice may
                        // have over-approximated a path that never runs.
                        (
                            Severity::Warning,
                            format!(
                                "may write read-only arg {p} (imprecise inferred window {win}) \
                                 — under-declared flow if any write executes"
                            ),
                        )
                    } else {
                        (
                            Severity::Error,
                            format!(
                                "writes {win} of read-only arg {p} — under-declared flow \
                                 (bind the argument mutable so the scheduler sees the hazard)"
                            ),
                        )
                    };
                    out.push(Diagnostic {
                        severity,
                        kernel: kernel.to_string(),
                        launch,
                        message,
                    });
                }
            }
        }
        if !d.eager && !d.prefetched && d.level == Level::Host {
            if let Some((iv, _)) = arg.read {
                let width = iv.clamp_window(d.dref.len).map_or(0, |(lo, hi)| hi - lo);
                if width >= STREAM_LINT_MIN && flagged_stream.insert(d.param) {
                    out.push(Diagnostic {
                        severity: Severity::Warning,
                        kernel: kernel.to_string(),
                        launch,
                        message: format!(
                            "streams {width} elements of arg {} from Host-level memory \
                             on demand with no prefetch annotation — each element is a \
                             blocking host round-trip",
                            d.param
                        ),
                    });
                }
            }
        }
    }
    out
}

/// One entry in the engine's launch table: everything needed to stage the
/// launch when its cores free up, the per-core runs while active, and the
/// parked result once complete.
struct Launch {
    id: u64,
    kernel: Kernel,
    /// Per-core bound arguments; consumed at activation.
    bound: Option<Vec<Vec<BoundArg>>>,
    options: OffloadOptions,
    core_ids: Vec<usize>,
    submitted_at: Time,
    launched_at: Time,
    /// Unsatisfied dependency edges (launch ids this one waits on).
    /// Elements are erased as the dependencies complete; the launch is
    /// eligible for core reservation only once this is empty.
    deps: Vec<u64>,
    /// Earliest activation time contributed by satisfied dependencies
    /// (the max of their finish times).
    dep_ready: Time,
    /// The launch's data-flow set (see [`FlowSpan`]); later submissions
    /// infer their edges against it.
    flows: Vec<FlowSpan>,
    /// Precise, unmerged external-argument declarations (see
    /// [`ExtArgDecl`]) — what the static verifier diffs inferred windows
    /// against. Outer index = core position.
    ext_args: Vec<Vec<ExtArgDecl>>,
    /// Statically inferred flow windows, computed at submit when
    /// verification is on (empty otherwise) — later `.independent()`
    /// submissions lint their inferred flows against these.
    inferred: Vec<InferredWindow>,
    /// Cores reserved (owner recorded) and the activation event scheduled.
    reserved: bool,
    active: bool,
    /// Slot is `None` only transiently while that core is being stepped.
    cores: Vec<Option<CoreRun>>,
    /// Cores not yet `Done`.
    live: usize,
    spills: u64,
    /// Parked completion: the result, or the error that killed this
    /// launch (claimed exactly once by `wait`).
    outcome: Option<Result<OffloadResult>>,
    /// Times this launch has been recovered after a transient fault.
    /// Compared against `options.retry` to decide recover-vs-abandon.
    attempts: u32,
    /// Last checkpoint taken (retry-enabled launches only; `None` until
    /// the first core suspends — a fault then restarts from scratch).
    /// Seeded at submit time when the launch resumes a migrated
    /// checkpoint (`OffloadOptions::restore`).
    checkpoint: Option<LaunchCheckpoint>,
}

#[derive(Debug)]
struct ExtBind {
    dref: DataRef,
    /// The variable's *home* level at bind time. Used for fast-path
    /// legality (`CoreLocal` short-circuit) and as the cost level for the
    /// bulk tensor-builtin path; element-request service costs are
    /// re-probed per access via [`MemRegistry::access_level`] so a
    /// shared-window cache hit is charged at `Shared` cost instead.
    level: Level,
    access: Access,
    pf: Option<PrefetchState>,
}

#[derive(Debug, Clone, Copy)]
enum WaitCtx {
    OnDemandRead,
    PrefetchRead { slot: usize, index: usize },
    WriteAck,
}

enum Status {
    /// VM not yet started; candidate = start time.
    Fresh,
    /// VM produced an outcome at `clock`; service it in global order.
    Pending(Outcome),
    /// Blocked on a transfer.
    Waiting { handle: Handle, ctx: WaitCtx, ready_at: Time },
    /// Channel was full; retry the outcome when a cell frees at `at`.
    Retry { outcome: Outcome, at: Time },
    /// Finished.
    Done,
}

struct CoreRun {
    id: usize,
    /// Owning launch (threaded through so access recording can attribute
    /// runtime external accesses to the launch being verified).
    launch: u64,
    vm: Interp,
    clock: Time,
    start: Time,
    channel: Channel,
    binds: Vec<ExtBind>,
    status: Status,
    stall: Time,
    result: Option<Value>,
    finished_at: Time,
    last_counters: CostCounters,
    eager_writebacks: Vec<(Rc<RefCell<Vec<f64>>>, DataRef)>,
    autoconsume: Vec<Handle>,
    /// Scheduler-visible suspensions serviced so far (throttles the
    /// checkpoint cadence — see [`CHECKPOINT_EVERY`]).
    suspensions: u64,
}

/// The engine: owns the memory registry, device model and PJRT executor.
pub struct Engine {
    tech: Technology,
    compute: ComputeModel,
    registry: MemRegistry,
    exec: Option<Rc<ModelExecutor>>,
    service: HostService,
    power: PowerModel,
    hidden: usize,
    now: Time,
    trace: Trace,
    stats: EngineStats,
    /// Reusable tile buffers for the tensor-builtin path (perf pass #2:
    /// gather/scatter previously allocated ~0.5 MB per call).
    scratch_a: Vec<f32>,
    scratch_b: Vec<f32>,
    /// Reusable f32↔f64 marshalling buffer for eager-copy launches and
    /// mutable-argument write-backs (perf pass #4).
    scratch_m: Vec<f32>,
    /// Inline prefetch-hit fast path enabled (see module docs). On by
    /// default; the differential tests switch it off to compare.
    fast_path: bool,
    /// The launch table: pending, active and completed-unclaimed launches
    /// in submission order.
    launches: Vec<Launch>,
    /// Global event heap over all active launches: `(candidate time,
    /// launch id, core position | EV_ACTIVATE)`. Ties resolve to the
    /// earlier-submitted launch, then the lower core position — for a
    /// single launch this is exactly the pre-queue scheduler's ordering.
    events: BinaryHeap<Reverse<(Time, u64, usize)>>,
    /// Per physical core: the launch currently reserving/occupying it.
    core_owner: Vec<Option<u64>>,
    /// Per physical core: virtual time it was last released (its final
    /// `finished_at` including teardown copy-backs).
    core_free: Vec<Time>,
    /// Ids of launches that failed, kept for the engine's lifetime so an
    /// explicit `.after` edge on a failed-and-claimed launch still parks
    /// [`Error::DependencyFailed`] (one u64 per failure — negligible).
    failed: HashSet<u64>,
    next_launch: u64,
    /// Installed fault schedule, consumed as faults strike (`None` = the
    /// common fault-free configuration, zero overhead).
    faults: Option<FaultPlan>,
    /// Fault/recovery accounting (injections, retries, checkpoint bytes…).
    fault_counters: FaultCounters,
    /// Virtual time the device was permanently lost, if it was. Once set,
    /// nothing activates here again; submits fail immediately.
    lost_at: Option<Time>,
    /// Checkpoints rescued at device loss for launches that still had
    /// retry budget, keyed by launch id: `(last checkpoint, remaining
    /// budget)`. The multi-device group claims these to migrate work to a
    /// surviving device ([`Engine::harvest_checkpoint`]). Ordered map:
    /// group migration scans survivors per harvested launch, so iteration
    /// order (if ever added) must be launch-id order, not hash order.
    harvested: BTreeMap<u64, (Option<LaunchCheckpoint>, u32)>,
    /// Static-verifier level applied at submit ([`VerifyLevel::Off`] by
    /// default — zero analysis overhead unless opted in).
    verify: VerifyLevel,
    /// Diagnostics accumulated by submit-time verification (capped at
    /// [`MAX_DIAGNOSTICS`]); drained via [`Engine::take_diagnostics`].
    diagnostics: Vec<Diagnostic>,
    /// When set, every external access the VM performs is appended to
    /// `observed` — the soundness fuzzer's runtime trace. Off by default.
    record_accesses: bool,
    /// Runtime external-access trace (see [`AccessRecord`]).
    observed: Vec<AccessRecord>,
    /// Kernel-summary cache keyed by program identity (`Rc::as_ptr`), so
    /// re-launching the same kernel never re-runs the fixpoint.
    /// Lookup-only — never iterated, so hash (and address) order can
    /// never leak into any observable (determinism sweep, PR 10).
    summaries: HashMap<usize, Rc<KernelSummary>>,
    /// Lowered linear-IR cache keyed by program identity — each program is
    /// lowered at most once, then shared by every compiled-tier launch.
    /// Lookup-only, never iterated (see `summaries`).
    lowered: HashMap<usize, Rc<LinearProgram>>,
    /// Per-program launch/dispatch history for [`TierChoice::Auto`].
    /// Lookup-only, never iterated (see `summaries`).
    tier_heat: HashMap<usize, TierHeat>,
    /// Per-tier execution accounting ([`Engine::tier_counters`]).
    tiers: TierCounters,
}

/// Submit-time diagnostics kept before older ones are dropped (bounds
/// memory for long-running sessions that never drain them).
const MAX_DIAGNOSTICS: usize = 1024;

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("tech", &self.tech.name)
            .field("now", &self.now)
            .field("stats", &self.stats)
            .finish()
    }
}

impl Engine {
    /// Build an engine for a technology. `exec` enables PJRT-backed tensor
    /// builtins (pass `None` for pure-VM sessions — tensor builtins then
    /// run native Rust fallbacks with identical numerics).
    pub fn new(
        tech: Technology,
        service_threads: usize,
        seed: u64,
        exec: Option<ModelExecutor>,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let service = HostService::new(&tech, service_threads, rng.fork(1));
        let compute = ComputeModel::new(&tech);
        let power = PowerModel::new(&tech);
        let hidden = exec.as_ref().map_or(100, |e| e.hidden());
        let cores = tech.cores;
        Engine {
            tech,
            compute,
            registry: MemRegistry::new(),
            exec: exec.map(Rc::new),
            service,
            power,
            hidden,
            now: 0,
            trace: Trace::disabled(),
            stats: EngineStats::default(),
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
            scratch_m: Vec::new(),
            fast_path: true,
            launches: Vec::new(),
            events: BinaryHeap::new(),
            core_owner: vec![None; cores],
            core_free: vec![0; cores],
            failed: HashSet::new(),
            next_launch: 0,
            faults: None,
            fault_counters: FaultCounters::default(),
            lost_at: None,
            harvested: BTreeMap::new(),
            verify: VerifyLevel::default(),
            diagnostics: Vec::new(),
            record_accesses: false,
            observed: Vec::new(),
            summaries: HashMap::new(),
            lowered: HashMap::new(),
            tier_heat: HashMap::new(),
            tiers: TierCounters::default(),
        }
    }

    /// Set the static-verification level applied at submit (default
    /// [`VerifyLevel::Off`]; see [`crate::analysis`]).
    pub fn set_verify(&mut self, level: VerifyLevel) {
        self.verify = level;
    }

    /// Current static-verification level.
    pub fn verify_level(&self) -> VerifyLevel {
        self.verify
    }

    /// Enable/disable runtime external-access recording (the soundness
    /// fuzzer's trace — see [`Engine::observed_accesses`]). Off by
    /// default; recording never changes virtual-time results.
    pub fn set_record_accesses(&mut self, on: bool) {
        self.record_accesses = on;
    }

    /// Runtime external accesses recorded so far (empty unless
    /// [`Engine::set_record_accesses`] was enabled).
    pub fn observed_accesses(&self) -> &[AccessRecord] {
        &self.observed
    }

    /// Drain the diagnostics accumulated by submit-time verification.
    pub fn take_diagnostics(&mut self) -> Vec<Diagnostic> {
        std::mem::take(&mut self.diagnostics)
    }

    /// Append a runtime external-access record (no-op unless recording).
    fn record_access(&mut self, launch: u64, dref: &DataRef, index: usize, write: bool) {
        if self.record_accesses {
            let lo = dref.offset + index;
            self.observed.push(AccessRecord { launch, buf: dref.id, lo, hi: lo + 1, write });
        }
    }

    /// Append a whole-view runtime access record (tensor builtins and
    /// eager copies move the full window at once).
    fn record_span(&mut self, launch: u64, dref: &DataRef, write: bool) {
        if self.record_accesses {
            self.observed.push(AccessRecord {
                launch,
                buf: dref.id,
                lo: dref.offset,
                hi: dref.offset + dref.len,
                write,
            });
        }
    }

    /// Push a verifier diagnostic, dropping beyond the cap.
    fn push_diagnostic(&mut self, d: Diagnostic) {
        if self.diagnostics.len() < MAX_DIAGNOSTICS {
            self.diagnostics.push(d);
        }
    }

    /// Summary for a kernel's program, computed once per distinct program.
    fn summary_for(&mut self, kernel: &Kernel) -> Rc<KernelSummary> {
        let key = Rc::as_ptr(&kernel.program) as usize;
        self.summaries
            .entry(key)
            .or_insert_with(|| Rc::new(crate::analysis::analyze_program(&kernel.program)))
            .clone()
    }

    /// Lowered linear IR for a kernel's program, computed once per
    /// distinct program (same identity-keyed cache as [`Self::summary_for`]).
    fn lowered_for(&mut self, kernel: &Kernel) -> Rc<LinearProgram> {
        let key = Rc::as_ptr(&kernel.program) as usize;
        if !self.lowered.contains_key(&key) {
            self.tiers.lowered_kernels += 1;
            self.lowered.insert(key, Rc::new(lower_program(&kernel.program)));
        }
        self.lowered[&key].clone()
    }

    /// Resolve the requested execution tier to a concrete one at submit
    /// time. `Auto` promotes once the program's history crosses either
    /// heuristic threshold ([`AUTO_COMPILE_LAUNCHES`] submissions or
    /// [`AUTO_COMPILE_DISPATCHES`] retired dispatches); any compiled
    /// choice is demoted back to the interpreter if the lowered image
    /// plus launch frame would overflow the core's local store — the
    /// same budget the static verifier lints
    /// ([`crate::analysis::lint`]'s kernel-budget check), applied to the
    /// image that would actually be pushed.
    fn resolve_tier(&mut self, kernel: &Kernel, choice: TierChoice) -> TierChoice {
        let key = Rc::as_ptr(&kernel.program) as usize;
        let heat = self.tier_heat.entry(key).or_default();
        heat.launches += 1;
        let mut tier = match choice {
            TierChoice::Auto => {
                if heat.launches >= AUTO_COMPILE_LAUNCHES
                    || heat.dispatches >= AUTO_COMPILE_DISPATCHES
                {
                    self.tiers.auto_promotions += 1;
                    TierChoice::Compiled
                } else {
                    TierChoice::Interp
                }
            }
            t => t,
        };
        if tier == TierChoice::Compiled {
            let lp = self.lowered_for(kernel);
            if lp.code_bytes() + FRAME_HEADER_BYTES > self.tech.local_store {
                self.tiers.budget_demotions += 1;
                tier = TierChoice::Interp;
            }
        }
        tier
    }

    /// Per-tier execution accounting accumulated so far.
    pub fn tier_counters(&self) -> TierCounters {
        self.tiers
    }

    /// Whole-graph pre-flight: re-derive the scheduler's edge set from the
    /// analyzer's inferred flows and diff it against the declared-flow
    /// edge set, re-running the per-launch flow lints over every launch
    /// still in the table. Call it after submitting and *before* waiting —
    /// `wait` retires launches from the table as results are claimed.
    /// Pure analysis: no virtual time advances and no launch state
    /// changes. Works at any [`VerifyLevel`], including `Off`.
    ///
    /// Edge derivation: `declared_edges` replays the scheduler's own
    /// predicate (explicit `.after` plus hull-flow conflicts, honouring
    /// `.independent()`); `inferred_edges` uses the union of analyzer
    /// windows and declared hulls and ignores `.independent()` — so the
    /// declared set is contained in the inferred set by construction, and
    /// the difference is exactly the dependencies the scheduler was told
    /// to ignore (plus any it honours only because flows were declared
    /// wider than the bytecode's real footprint).
    pub fn verify_graph(&mut self) -> GraphReport {
        let mut report = GraphReport::default();
        // Snapshot what the analysis needs (kernel clones are two Rc
        // bumps) so the summary cache can grow while iterating.
        let snaps: Vec<_> = self
            .launches
            .iter()
            .map(|l| {
                (
                    l.id,
                    l.kernel.clone(),
                    l.ext_args.clone(),
                    l.flows.clone(),
                    l.options.flow_deps,
                    l.options.after.iter().map(|d| d.0).collect::<Vec<u64>>(),
                    l.outcome.as_ref().is_some_and(|o| o.is_err()),
                )
            })
            .collect();
        // Per included launch: (id, pure analyzer windows, declared
        // hulls, union of both, flow_deps, explicit deps).
        struct Node {
            id: u64,
            name: String,
            pure: Vec<InferredWindow>,
            hull: Vec<InferredWindow>,
            union: Vec<InferredWindow>,
            flow_deps: bool,
            after: Vec<u64>,
        }
        let mut nodes: Vec<Node> = Vec::new();
        for (id, kernel, ext_args, flows, flow_deps, after, failed) in snaps {
            if failed {
                report.skipped += 1;
                continue;
            }
            let summary = self.summary_for(&kernel);
            let pure = inferred_windows(&summary, &ext_args);
            report.diagnostics.extend(lint_flows(&summary, &ext_args, Some(id), kernel.name()));
            let hull = hull_windows(&flows);
            let mut union = pure.clone();
            union.extend(hull.iter().copied());
            report.launches.push(LaunchFlowReport {
                launch: id,
                kernel: kernel.name().to_string(),
                windows: pure.clone(),
            });
            nodes.push(Node {
                id,
                name: kernel.name().to_string(),
                pure,
                hull,
                union,
                flow_deps,
                after,
            });
        }
        let conflict = |a: &[InferredWindow], b: &[InferredWindow]| {
            a.iter().any(|x| b.iter().any(|y| x.conflicts(y)))
        };
        for j in 1..nodes.len() {
            for i in 0..j {
                let (earlier, later) = (&nodes[i], &nodes[j]);
                let explicit = later.after.contains(&earlier.id);
                if explicit || (later.flow_deps && conflict(&later.hull, &earlier.hull)) {
                    report.declared_edges.push((earlier.id, later.id));
                }
                if explicit || conflict(&later.union, &earlier.union) {
                    report.inferred_edges.push((earlier.id, later.id));
                }
                if !later.flow_deps && conflict(&later.pure, &earlier.pure) {
                    report.diagnostics.push(Diagnostic {
                        severity: Severity::Warning,
                        kernel: later.name.clone(),
                        launch: Some(later.id),
                        message: format!(
                            "launch {} declared .independent() but its inferred flows \
                             conflict with launch {} — the scheduler will not order them",
                            later.id, earlier.id
                        ),
                    });
                }
            }
        }
        report
    }

    /// Install a seeded fault schedule (see [`FaultPlan`]). Faults are
    /// delivered through the engine's event loop on the shared virtual
    /// timeline: a core fault strikes at the next suspension point of
    /// whatever launch occupies the core, device loss kills every
    /// in-flight launch. Installing a plan replaces any previous one.
    pub fn install_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(plan);
    }

    /// Fault/recovery accounting so far (all-zero without a fault plan).
    pub fn fault_counters(&self) -> FaultCounters {
        self.fault_counters
    }

    /// Virtual time the device was permanently lost, if it was.
    pub fn device_lost(&self) -> Option<Time> {
        self.lost_at
    }

    /// Claim the checkpoint rescued for `id` at device loss: `(last
    /// checkpoint — `None` means restart from arguments, remaining retry
    /// budget)`. Present only for launches that were in flight when the
    /// device died *and* still had budget; each entry is claimed at most
    /// once. The multi-device group redeems this to resume the launch on
    /// a surviving device ([`OffloadOptions::restore`]).
    pub fn harvest_checkpoint(
        &mut self,
        id: LaunchId,
    ) -> Option<(Option<LaunchCheckpoint>, u32)> {
        self.harvested.remove(&id.0)
    }

    /// Enable/disable the inline prefetch-hit fast path (module docs).
    /// Virtual-time results are bit-identical either way; disabling only
    /// costs wall-clock. Exists for differential testing.
    pub fn set_fast_path(&mut self, on: bool) {
        self.fast_path = on;
    }

    /// Enable event tracing (bounded).
    pub fn enable_trace(&mut self, capacity: usize) {
        self.trace = Trace::bounded(capacity);
    }

    /// The trace (render with [`Trace::render`]).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// The technology preset in use.
    pub fn tech(&self) -> &Technology {
        &self.tech
    }

    /// Memory registry (allocate/read variables).
    pub fn registry(&self) -> &MemRegistry {
        &self.registry
    }

    /// Mutable registry access.
    pub fn registry_mut(&mut self) -> &mut MemRegistry {
        &mut self.registry
    }

    /// Aggregate shared-window cache accounting across all live variables
    /// (all-zero when none are cache-fronted).
    pub fn cache_counters(&self) -> CacheCounters {
        self.registry.total_cache_counters()
    }

    /// Host service (link stats, bandwidth degradation knobs).
    pub fn service_mut(&mut self) -> &mut HostService {
        &mut self.service
    }

    /// Host service (read-only).
    pub fn service(&self) -> &HostService {
        &self.service
    }

    /// Engine statistics.
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// The latest virtual time any core is reserved through — the
    /// device's true busy-until. `now` advances only when a launch
    /// *completes* ([`Engine::complete`]); a **failed** launch instead
    /// releases its cores at their stamped progress via `core_free`
    /// without ever completing, so after a failure `now` can lag the
    /// horizon. Anything scheduling *future* work against this device
    /// (the fleet's analytic slot watermark) must use the horizon, or it
    /// will book an instant the device is still busy.
    pub fn core_horizon(&self) -> Time {
        self.core_free.iter().copied().fold(self.now, Time::max)
    }

    /// Energy consumed so far (Joules, integrated over offloads).
    pub fn energy(&self) -> f64 {
        self.power.energy()
    }

    /// PJRT executor, if attached.
    pub fn executor(&self) -> Option<&Rc<ModelExecutor>> {
        self.exec.as_ref()
    }

    /// Run a kernel across cores, blocking until it completes (the paper's
    /// default collective). Literally [`Engine::submit`] + [`Engine::wait`]
    /// — already-submitted launches keep progressing on the shared
    /// timeline while this one runs.
    pub fn offload(
        &mut self,
        kernel: &Kernel,
        bound: Vec<Vec<BoundArg>>,
        options: &OffloadOptions,
        core_ids: &[usize],
    ) -> Result<OffloadResult> {
        let id = self.submit(kernel, bound, options, core_ids)?;
        self.wait(id)
    }

    /// Enqueue a launch without blocking and without advancing virtual
    /// time. Dependency edges are attached here: the explicit
    /// [`OffloadOptions::after`] list plus edges inferred from data flow
    /// (this launch's argument read/write windows against every in-flight
    /// launch's — module docs). The launch activates — stages code
    /// pushes, eager copies and pre-fetch warm-up — once **all its edges
    /// are satisfied** and every core in `core_ids` is free, at
    /// `max(submit, dependency finishes, core releases)`; until then it
    /// is deterministically queued (submission order among ready
    /// launches). A forward or self `.after` edge is rejected here (cycle
    /// rejection — edges may only point at already-submitted launches, so
    /// the graph is acyclic by construction); an `.after` edge on a
    /// launch that already failed parks [`Error::DependencyFailed`] as
    /// this launch's outcome. Redeem the id with [`Engine::wait`];
    /// progress happens inside `wait`/`wait_all`/`poll`, never
    /// spontaneously.
    pub fn submit(
        &mut self,
        kernel: &Kernel,
        bound: Vec<Vec<BoundArg>>,
        options: &OffloadOptions,
        core_ids: &[usize],
    ) -> Result<LaunchId> {
        debug_assert_eq!(bound.len(), core_ids.len());
        if core_ids.is_empty() {
            return Err(Error::Coordinator("launch requires at least one core".into()));
        }
        self.tech.validate_cores(core_ids)?;
        let id = self.next_launch;

        // ---- dependency edges ----
        // Cycle rejection: an edge may only point at a launch submitted
        // strictly earlier, so every edge points "backwards" and the
        // graph cannot contain a cycle.
        for d in &options.after {
            if d.0 >= id {
                return Err(Error::Coordinator(format!(
                    "dependency cycle rejected: launch {id} cannot wait on launch {} — \
                     edges may only name already-submitted launches",
                    d.0
                )));
            }
        }
        // The flow set is recorded unconditionally — `flow_deps: false`
        // only stops *this* launch from waiting on inferred edges; later
        // submissions still infer edges against it, and
        // [`Engine::quiesce`] still sees it (an opted-out launch is
        // unordered, not invisible).
        let flows = collect_flows(&bound);
        let ext_args = collect_ext_args(&bound, &self.registry);

        // ---- static verification (see `crate::analysis`) ----
        // Runs before any engine state mutates, so a Strict rejection
        // leaves the launch table, event heap and id counter untouched.
        let mut inferred: Vec<InferredWindow> = Vec::new();
        if self.verify != VerifyLevel::Off {
            let summary = self.summary_for(kernel);
            inferred = inferred_windows(&summary, &ext_args);
            let mut diags = lint_flows(&summary, &ext_args, Some(id), kernel.name());
            if !options.flow_deps {
                // `.independent()` opt-out whose *inferred* flows conflict
                // with an in-flight launch: the weak cross-launch memory
                // model applies to a race the bytecode really has.
                let mine = &inferred;
                for l in self.launches.iter().filter(|l| l.outcome.is_none()) {
                    let theirs = if l.inferred.is_empty() {
                        hull_windows(&l.flows)
                    } else {
                        l.inferred.clone()
                    };
                    if let Some((a, b)) = mine
                        .iter()
                        .flat_map(|a| theirs.iter().map(move |b| (a, b)))
                        .find(|&(a, b)| a.conflicts(b))
                    {
                        diags.push(Diagnostic {
                            severity: Severity::Warning,
                            kernel: kernel.name().to_string(),
                            launch: Some(id),
                            message: format!(
                                "declared .independent() but inferred flows conflict with \
                                 in-flight launch {} on buffer {} ([{}, {}) vs [{}, {}))",
                                l.id, a.buf, a.lo, a.hi, b.lo, b.hi
                            ),
                        });
                    }
                }
            }
            if self.verify == VerifyLevel::Strict {
                if let Some(d) = diags.iter().find(|d| d.severity == Severity::Error) {
                    return Err(Error::Analysis {
                        launch: Some(id),
                        diagnostic: d.to_string(),
                    });
                }
            }
            for d in diags {
                self.push_diagnostic(d);
            }
        }

        let mut deps: Vec<u64> = Vec::new();
        // External-dependency floor: the multi-device group threads its
        // cross-device staging completion time in here, so it composes
        // with in-engine edges exactly like a satisfied dependency.
        let mut dep_ready: Time = options.not_before;
        let mut dep_error: Option<Error> = None;
        // An explicit edge on a launch that failed and was already
        // claimed (retired from the table) still abandons this launch.
        for d in &options.after {
            if self.failed.contains(&d.0) {
                dep_error =
                    Some(Error::DependencyFailed { launch: id, dep: d.0, dep_device: None });
            }
        }
        for l in &self.launches {
            let explicit = options.after.iter().any(|d| d.0 == l.id);
            let inferred = options.flow_deps
                && flows.iter().any(|f| l.flows.iter().any(|g| f.conflicts(g)));
            if !explicit && !inferred {
                continue;
            }
            match &l.outcome {
                // In flight: a real edge.
                None => deps.push(l.id),
                // Completed, unclaimed: satisfied — only its finish time
                // matters (already ≤ the `now` watermark, kept for
                // robustness).
                Some(Ok(res)) => dep_ready = dep_ready.max(res.finished_at),
                // Failed, unclaimed: an explicit edge abandons this
                // launch. An *inferred* edge does not — that matches the
                // blocking sequence, where the caller saw the error from
                // their own wait and chose to keep submitting.
                Some(Err(_)) if explicit => {
                    dep_error =
                        Some(Error::DependencyFailed { launch: id, dep: l.id, dep_device: None });
                }
                Some(Err(_)) => {}
            }
        }
        deps.sort_unstable();
        deps.dedup();

        // ---- execution-tier resolution ----
        // `Auto` resolves to a concrete tier *now* and the resolved tier is
        // what the launch records, so fault-retry re-activations and
        // harvested-checkpoint migrations replay the same tier.
        let mut options = options.clone();
        options.tier = self.resolve_tier(kernel, options.tier);

        self.next_launch += 1;
        self.launches.push(Launch {
            id,
            kernel: kernel.clone(),
            bound: Some(bound),
            options: options.clone(),
            core_ids: core_ids.to_vec(),
            submitted_at: self.now,
            launched_at: self.now,
            deps,
            dep_ready,
            flows,
            inferred,
            ext_args,
            reserved: false,
            active: false,
            cores: Vec::new(),
            live: core_ids.len(),
            spills: 0,
            outcome: None,
            attempts: 0,
            checkpoint: options.restore.as_deref().cloned(),
        });
        if self.lost_at.is_some() {
            // The device is gone: nothing submitted here can ever run.
            // CoreFault (transient) lets a multi-device caller route the
            // work elsewhere instead of treating it as a kernel bug.
            let li = self.launches.len() - 1;
            self.fault_counters.abandoned += 1;
            self.fail_launch(li, Error::CoreFault { core: core_ids[0], launch: id });
        } else if let Some(e) = dep_error {
            let li = self.launches.len() - 1;
            self.fail_launch(li, e);
        }
        self.reserve_ready();
        Ok(LaunchId(id))
    }

    /// Drive the timeline until launch `id` completes; claim and return
    /// its result — or the error that killed it (a failing launch parks
    /// its own error and never poisons another launch's wait). Waiting on
    /// an id twice is an error. Other in-flight launches progress as a
    /// side effect — their outcomes stay parked for their own `wait`.
    pub fn wait(&mut self, id: LaunchId) -> Result<OffloadResult> {
        loop {
            let Some(pos) = self.launches.iter().position(|l| l.id == id.0) else {
                return Err(Error::Coordinator(format!(
                    "launch {} is unknown or already waited",
                    id.0
                )));
            };
            if self.launches[pos].outcome.is_some() {
                let l = self.launches.remove(pos);
                return l.outcome.expect("checked above");
            }
            if !self.drive_one()? {
                return Err(Error::Coordinator(
                    "launch queue stalled: in-flight launches but no runnable events".into(),
                ));
            }
        }
    }

    /// Drive the timeline until every submitted launch has completed (or
    /// failed). Outcomes stay parked — including per-launch errors —
    /// until claimed with [`Engine::wait`], which then returns
    /// immediately; unclaimed outcomes are retained for the session's
    /// lifetime, so long fire-and-forget loops should wait their handles
    /// to reclaim the memory.
    pub fn wait_all(&mut self) -> Result<()> {
        while self.launches.iter().any(|l| l.outcome.is_none()) {
            if !self.drive_one()? {
                return Err(Error::Coordinator(
                    "launch queue stalled: in-flight launches but no runnable events".into(),
                ));
            }
        }
        Ok(())
    }

    /// Drive the timeline until *some* launch is complete and unclaimed,
    /// returning its id (`None` when nothing is in flight). Repeated calls
    /// return the same id until it is `wait`ed.
    pub fn poll(&mut self) -> Result<Option<LaunchId>> {
        loop {
            if let Some(l) = self.launches.iter().find(|l| l.outcome.is_some()) {
                return Ok(Some(LaunchId(l.id)));
            }
            if !self.drive_one()? {
                return Ok(None);
            }
        }
    }

    /// Lifecycle stage of a submitted launch; `None` once waited (or never
    /// submitted). Distinguishes [`LaunchStatus::Blocked`] (waiting on
    /// dependency edges) from [`LaunchStatus::Pending`] (edges satisfied,
    /// queued on core contention or not yet driven).
    pub fn launch_status(&self, id: LaunchId) -> Option<LaunchStatus> {
        self.launches.iter().find(|l| l.id == id.0).map(|l| {
            if l.outcome.is_some() {
                LaunchStatus::Completed
            } else if l.active {
                LaunchStatus::Active
            } else if !l.deps.is_empty() {
                LaunchStatus::Blocked
            } else {
                LaunchStatus::Pending
            }
        })
    }

    /// Launches submitted but not yet complete (blocked + pending +
    /// active). See [`Engine::queue_stats`] for the per-stage breakdown.
    pub fn in_flight(&self) -> usize {
        self.launches.iter().filter(|l| l.outcome.is_none()).count()
    }

    /// Whether a launch ever failed (its own error or a propagated
    /// `DependencyFailed`). Unlike [`Engine::launch_status`] this stays
    /// answerable after the outcome is claimed — the failed set is kept
    /// for the engine's lifetime. The multi-device group consults it to
    /// decide whether a cross-device staging source is poisoned.
    pub fn launch_failed(&self, id: LaunchId) -> bool {
        self.failed.contains(&id.0)
    }

    /// Physical cores currently reserved or occupied by a launch. The
    /// multi-device group's automatic placement reads this as the
    /// per-device occupancy signal.
    pub fn busy_cores(&self) -> usize {
        self.core_owner.iter().filter(|o| o.is_some()).count()
    }

    /// Per-stage breakdown of the launch table — blocked on dependency
    /// edges vs queued on core contention vs active vs
    /// completed-unclaimed.
    pub fn queue_stats(&self) -> QueueStats {
        let mut qs = QueueStats::default();
        for l in &self.launches {
            if l.outcome.is_some() {
                qs.completed += 1;
            } else if l.active {
                qs.active += 1;
            } else if !l.deps.is_empty() {
                qs.blocked += 1;
            } else {
                qs.pending += 1;
            }
        }
        qs
    }

    /// As [`Engine::queue_stats`], restricted to launches tagged with
    /// `tenant` via [`crate::coordinator::OffloadOptions::tenant`]. The
    /// fleet's fairness accounting reads this; untagged launches never
    /// match.
    pub fn queue_stats_for_tenant(&self, tenant: u64) -> QueueStats {
        let mut qs = QueueStats::default();
        for l in self.launches.iter().filter(|l| l.options.tenant == Some(tenant)) {
            if l.outcome.is_some() {
                qs.completed += 1;
            } else if l.active {
                qs.active += 1;
            } else if !l.deps.is_empty() {
                qs.blocked += 1;
            } else {
                qs.pending += 1;
            }
        }
        qs
    }

    /// Drive the timeline until no in-flight launch's data-flow set can
    /// alias `dref` (their outcomes stay parked for their own waits).
    /// Host-side code about to read or write a variable directly calls
    /// this to order itself after the device work touching it — the shard
    /// planner drains the base variable this way before gather staging.
    pub fn quiesce(&mut self, dref: DataRef) -> Result<()> {
        loop {
            // Abandoned flows count as drained: a launch whose outcome is
            // parked (including every transitively-abandoned dependent of
            // a fault or failure) will never touch the variable again, so
            // waiting on it would spin the full graph for nothing — or,
            // after device loss empties the event heap, stall forever.
            // The `failed` check is belt-and-braces: `fail_launch` always
            // parks an outcome synchronously, but quiesce must never spin
            // on a failed launch even if that coupling ever loosens.
            let busy = self.launches.iter().any(|l| {
                l.outcome.is_none()
                    && !self.failed.contains(&l.id)
                    && l.flows.iter().any(|f| f.touches(&dref))
            });
            if !busy {
                return Ok(());
            }
            if !self.drive_one()? {
                return Err(Error::Coordinator(
                    "launch queue stalled: in-flight launches but no runnable events".into(),
                ));
            }
        }
    }

    /// Reserve cores for every launch whose dependency edges are all
    /// satisfied and whose core set is entirely free, in submission
    /// order, and schedule its activation event at `max(submit time,
    /// dependencies' finish times, last release time of its cores)`.
    ///
    /// The scan is *work-conserving*, not strict FIFO: launches that
    /// mutually contend for a core are reserved in submission order, but
    /// a later ready launch starts ahead of an earlier launch still
    /// blocked on a different core or on a dependency edge (no
    /// head-of-line blocking). Deterministic either way; a pending launch
    /// can be deferred indefinitely only by a caller who keeps submitting
    /// conflicting work before driving it to completion.
    fn reserve_ready(&mut self) {
        if self.lost_at.is_some() {
            return; // a lost device never activates anything again
        }
        for li in 0..self.launches.len() {
            let l = &self.launches[li];
            if l.reserved || l.outcome.is_some() || !l.deps.is_empty() {
                continue;
            }
            if l.core_ids.iter().any(|&c| self.core_owner[c].is_some()) {
                continue;
            }
            let id = l.id;
            let mut at = l.submitted_at.max(l.dep_ready);
            for &c in &self.launches[li].core_ids {
                self.core_owner[c] = Some(id);
                at = at.max(self.core_free[c]);
            }
            self.launches[li].reserved = true;
            self.events.push(Reverse((at, id, EV_ACTIVATE)));
        }
    }

    /// A dependency completed at `finish`: erase its edge from every
    /// launch still waiting on it and raise their earliest activation
    /// time to its finish.
    fn resolve_deps(&mut self, id: u64, finish: Time) {
        for l in &mut self.launches {
            let before = l.deps.len();
            l.deps.retain(|&d| d != id);
            if l.deps.len() != before {
                l.dep_ready = l.dep_ready.max(finish);
            }
        }
    }

    /// Process one event from the global heap: activate a launch or step
    /// one core at its candidate time. Returns `false` when the heap is
    /// empty (nothing active). On error the offending launch is dropped
    /// and its cores released, so the engine stays usable.
    fn drive_one(&mut self) -> Result<bool> {
        let Some(Reverse((t, id, pos))) = self.events.pop() else {
            return Ok(false);
        };
        // Permanent device loss fires before any event at or after its
        // scheduled time (the popped event is moot — `device_loss` clears
        // the heap anyway).
        if let Some(at) = self.faults.as_ref().and_then(FaultPlan::device_loss_at) {
            if at <= t && self.lost_at.is_none() {
                self.device_loss(at);
                return Ok(true);
            }
        }
        // Stale event for a launch already waited/aborted.
        let Some(li) = self.launches.iter().position(|l| l.id == id) else {
            return Ok(true);
        };
        if pos == EV_ACTIVATE {
            if let Err(e) = self.activate(li, t) {
                self.fail_launch(li, e);
            }
            return Ok(true);
        }
        match self.launches[li]
            .cores
            .get(pos)
            .and_then(|c| c.as_ref())
            .and_then(|c| Self::candidate(c))
        {
            Some(cand) if cand == t => {}
            Some(cand) => {
                self.events.push(Reverse((cand, id, pos))); // stale entry
                return Ok(true);
            }
            None => return Ok(true),
        }
        // An armed core fault strikes *here*: the core has reached the
        // suspension point the scheduler is about to service, and loses
        // its in-flight work instead of being stepped.
        let cid = self.launches[li].core_ids[pos];
        if let Some(kind) = self.faults.as_mut().and_then(|p| p.take_fault(cid, t)) {
            self.fault_counters.injected += 1;
            self.trace.emit(t, cid, "fault", format!("{kind:?}"));
            if self.launches[li].attempts < self.launches[li].options.retry {
                self.recover_launch(li, t);
            } else {
                let lid = self.launches[li].id;
                self.fault_counters.abandoned += 1;
                self.fail_launch(li, Error::CoreFault { core: cid, launch: lid });
            }
            return Ok(true);
        }
        let mut core = self.launches[li].cores[pos].take().expect("core parked");
        let stepped = self.step_core(&mut core, t);
        if stepped.is_ok() {
            // Refresh this core's checkpoint entry while the launch still
            // owns the scheduler slot, so the Shared-level write lands in
            // the core's own time (cost-modeled, never free).
            self.refresh_checkpoint(li, pos, &mut core);
        }
        let next = Self::candidate(&core);
        let done = matches!(core.status, Status::Done);
        self.launches[li].cores[pos] = Some(core);
        if let Err(e) = stepped {
            self.fail_launch(li, e);
            return Ok(true);
        }
        if let Some(nt) = next {
            self.events.push(Reverse((nt, id, pos)));
        }
        if done {
            self.launches[li].live -= 1;
            if self.launches[li].live == 0 {
                if let Err(e) = self.complete(li) {
                    self.fail_launch(li, e);
                }
            }
        }
        Ok(true)
    }

    /// Park an error as launch `li`'s outcome, release its cores so the
    /// rest of the queue keeps running, and abandon its transitive
    /// dependents: every launch with an edge (explicit or inferred) on a
    /// failed launch parks its *own* [`Error::DependencyFailed`] —
    /// claimed by its own `wait`, never surfacing from another launch's —
    /// while launches with no path to the failure are untouched.
    /// Remaining heap events for the launch become stale no-ops (its core
    /// slots are dropped; dependents were blocked, so they hold neither
    /// cores nor events).
    fn fail_launch(&mut self, li: usize, e: Error) {
        // Release each core no earlier than the failed launch's own
        // progress on it (its next candidate time covers in-flight
        // transfer arrivals), so a queued successor cannot activate at a
        // virtual time before effects the failed launch already stamped
        // into the registry and trace.
        let releases: Vec<(usize, Time)> = self.launches[li]
            .cores
            .iter()
            .flatten()
            .map(|c| (c.id, Self::candidate(c).unwrap_or(0).max(c.clock).max(c.finished_at)))
            .collect();
        for (cid, t) in releases {
            self.core_free[cid] = self.core_free[cid].max(t);
        }
        let l = &mut self.launches[li];
        l.cores.clear();
        l.outcome = Some(Err(e));
        let id = l.id;
        self.failed.insert(id);
        let core_ids = l.core_ids.clone();
        for &c in &core_ids {
            if self.core_owner[c] == Some(id) {
                self.core_owner[c] = None;
            }
        }
        let mut worklist = vec![id];
        while let Some(fid) = worklist.pop() {
            let dependents: Vec<usize> = self
                .launches
                .iter()
                .enumerate()
                .filter(|(_, l)| l.outcome.is_none() && l.deps.contains(&fid))
                .map(|(i, _)| i)
                .collect();
            for di in dependents {
                let dl = &mut self.launches[di];
                let did = dl.id;
                dl.cores.clear();
                dl.outcome =
                    Some(Err(Error::DependencyFailed { launch: did, dep: fid, dep_device: None }));
                self.failed.insert(did);
                worklist.push(did);
            }
        }
        self.reserve_ready();
    }

    /// Deep-copy a value so a checkpoint cannot alias live VM state
    /// (arrays are `Rc`-shared on ordinary clone).
    fn deep_copy_value(v: &Value) -> Value {
        match v {
            Value::Array(a) => Value::array(a.borrow().clone()),
            other => other.clone(),
        }
    }

    /// Refresh core `pos`'s entry in launch `li`'s checkpoint if this is a
    /// checkpointable suspension: `Pending(ExtRead/ExtWrite)` on the
    /// [`CHECKPOINT_EVERY`] cadence, core completion always. No-op for
    /// launches without a retry budget or a migrated checkpoint — the
    /// fail-fast default pays nothing (and its timing is untouched: the
    /// checkpoint's Shared-level write advances the core clock).
    fn refresh_checkpoint(&mut self, li: usize, pos: usize, c: &mut CoreRun) {
        let l = &self.launches[li];
        if l.options.retry == 0 && l.options.restore.is_none() {
            return;
        }
        let resume = match &c.status {
            Status::Pending(Outcome::ExtRead { slot, index }) => {
                c.suspensions += 1;
                if c.suspensions % CHECKPOINT_EVERY != 1 {
                    return;
                }
                ResumePoint::Read { slot: *slot, index: *index }
            }
            Status::Pending(Outcome::ExtWrite { slot, index, value }) => {
                c.suspensions += 1;
                if c.suspensions % CHECKPOINT_EVERY != 1 {
                    return;
                }
                ResumePoint::Write { slot: *slot, index: *index, value: *value }
            }
            Status::Done => {
                ResumePoint::Done { result: c.result.as_ref().map(Self::deep_copy_value) }
            }
            // Waiting/Retry/Fresh and Done/Tensor outcomes are not clean
            // resume points (in-flight channel handles do not survive a
            // restore); the previous checkpoint stays in force.
            _ => return,
        };
        let roots: Vec<Rc<RefCell<Vec<f64>>>> =
            c.eager_writebacks.iter().map(|(a, _)| Rc::clone(a)).collect();
        let (vm, wb_roots) = c.vm.snapshot(&roots);
        let pf_cursors: Vec<(usize, usize)> = c
            .binds
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.pf.as_ref().map(|p| (i, p.cursor())))
            .collect();
        let result_bytes = match &resume {
            ResumePoint::Done { result: Some(Value::Array(a)) } => (a.borrow().len() * 8) as u64,
            _ => 0,
        };
        let bytes = vm.byte_size() + result_bytes + 32;
        let cc = CoreCheckpoint { vm, wb_roots, resume, stall: c.stall, pf_cursors, bytes };
        // The snapshot travels to Shared-level storage: charge the write
        // in this core's own time so recovery readiness is never free.
        if matches!(c.status, Status::Done) {
            c.finished_at = self.service.service(c.finished_at, Level::Shared, bytes);
        } else {
            c.clock = self.service.service(c.clock, Level::Shared, bytes);
        }
        self.fault_counters.checkpoint_bytes += bytes;
        let ncores = self.launches[li].core_ids.len();
        let ck = self.launches[li]
            .checkpoint
            .get_or_insert_with(|| LaunchCheckpoint { cores: vec![None; ncores], bytes: 0 });
        if ck.cores.len() != ncores {
            // Migrated checkpoint from a device with a different core-set
            // length (defensive; the group resubmits with matching arity).
            ck.cores.resize(ncores, None);
        }
        ck.cores[pos] = Some(cc);
        ck.bytes = ck.cores.iter().flatten().map(|c| c.bytes).sum();
    }

    /// A transient fault struck launch `li` and it has retry budget:
    /// release its cores, charge the Shared-level read that restores its
    /// last checkpoint, apply the configured back-off, and requeue it on
    /// the same device. The replay is deterministic — registry writes are
    /// issued at service time and replaying a checkpoint re-issues the
    /// identical writes — so a recovered run's results, losses and final
    /// buffer contents are bit-identical to its fault-free twin; only the
    /// clock and the fault counters differ (engine invariant 10).
    fn recover_launch(&mut self, li: usize, at: Time) {
        self.fault_counters.retried += 1;
        self.launches[li].attempts += 1;
        // Release each core no earlier than the launch's own progress on
        // it, exactly as `fail_launch` does, so requeued or competing
        // launches cannot activate before already-stamped effects.
        let releases: Vec<(usize, Time)> = self.launches[li]
            .cores
            .iter()
            .flatten()
            .map(|c| (c.id, Self::candidate(c).unwrap_or(0).max(c.clock).max(c.finished_at)))
            .collect();
        for (cid, t) in releases {
            self.core_free[cid] = self.core_free[cid].max(t);
        }
        let id = self.launches[li].id;
        for &c in &self.launches[li].core_ids.clone() {
            if self.core_owner[c] == Some(id) {
                self.core_owner[c] = None;
            }
        }
        // Restore cost: one Shared-level read of the checkpoint (zero
        // bytes — a from-scratch restart — reads nothing), then back-off.
        let bytes = self.launches[li].checkpoint.as_ref().map_or(0, LaunchCheckpoint::bytes);
        let restored = if bytes > 0 { self.service.service(at, Level::Shared, bytes) } else { at };
        let resume_at = restored + self.launches[li].options.backoff;
        self.fault_counters.recovery_time += resume_at.saturating_sub(at);
        let l = &mut self.launches[li];
        l.cores.clear();
        l.reserved = false;
        l.active = false;
        l.live = l.core_ids.len();
        l.dep_ready = l.dep_ready.max(resume_at);
        let attempt = l.attempts;
        self.trace.emit(at, self.launches[li].core_ids[0], "retry", format!(
            "launch {id} attempt {attempt}, resume at {resume_at}"
        ));
        // Stale heap events for the old incarnation revalidate against the
        // re-activated cores' candidates and re-push or drop — benign.
        self.reserve_ready();
    }

    /// Permanent device loss at `at`: every in-flight launch fails with
    /// [`Error::CoreFault`]; launches that still had retry budget first
    /// park their last checkpoint in the harvest table so a multi-device
    /// group can migrate them to a surviving device. The event heap is
    /// cleared — nothing on this device ever runs again — but parked
    /// outcomes (successes included) remain claimable, and `quiesce`
    /// treats the abandoned flows as drained.
    fn device_loss(&mut self, at: Time) {
        self.lost_at = Some(at);
        self.fault_counters.injected += 1;
        self.trace.emit(at, 0, "device-loss", "");
        // Harvest first: `fail_launch` cascades DependencyFailed through
        // dependents, and a dependent with its own budget deserves its
        // checkpoint in the table before the cascade reaches it.
        let rescued: Vec<(u64, Option<LaunchCheckpoint>, u32)> = self
            .launches
            .iter()
            .filter(|l| l.outcome.is_none())
            .filter_map(|l| {
                let budget = l.options.retry.saturating_sub(l.attempts);
                (budget > 0).then(|| (l.id, l.checkpoint.clone(), budget))
            })
            .collect();
        for (id, ck, budget) in rescued {
            self.harvested.insert(id, (ck, budget));
        }
        while let Some(li) = self.launches.iter().position(|l| l.outcome.is_none()) {
            let id = self.launches[li].id;
            let core = self.launches[li].core_ids.first().copied().unwrap_or(0);
            if !self.harvested.contains_key(&id) {
                self.fault_counters.abandoned += 1;
            }
            self.fail_launch(li, Error::CoreFault { core, launch: id });
        }
        self.events.clear();
    }

    /// Stage launch `li` onto its (free) cores at virtual time `at`: code
    /// pushes, eager copies / spills, reference binding, and the pre-fetch
    /// warm-up — the classic blocking launch sequence, verbatim.
    fn activate(&mut self, li: usize, at: Time) -> Result<()> {
        // Retry-enabled launches keep their bound arguments so a faulted
        // incarnation can be re-staged; fail-fast launches (the default)
        // consume them exactly as before.
        let retryable = self.launches[li].options.retry > 0
            || self.launches[li].options.restore.is_some();
        let bound = if retryable {
            self.launches[li].bound.clone().expect("bound retained for retry")
        } else {
            self.launches[li].bound.take().expect("activated exactly once")
        };
        // The checkpoint (if any) seeds per-core restores below; it is
        // re-armed on the launch afterwards so a fault arriving before
        // the next refresh restores the same state again.
        let ck = self.launches[li].checkpoint.take();
        let kernel = self.launches[li].kernel.clone();
        let options = self.launches[li].options.clone();
        let core_ids = self.launches[li].core_ids.clone();
        let id = self.launches[li].id;
        let launch = at;
        let mut spills = 0u64;
        let mut cores: Vec<CoreRun> = Vec::with_capacity(core_ids.len());

        // Compiled-tier launches push the *lowered* image (pre-resolved
        // linear IR, typically wider per instruction but fewer of them) —
        // MemKind placement and transfer costing see the bytes that
        // actually travel. The tier was resolved at submit, so the budget
        // demotion already guaranteed this image fits the local store.
        let lowered = if options.tier == TierChoice::Compiled {
            Some(self.lowered_for(&kernel))
        } else {
            None
        };
        let image_bytes = match &lowered {
            Some(lp) => lp.code_bytes(),
            None => kernel.code_bytes(),
        };
        match options.tier {
            TierChoice::Compiled => self.tiers.compiled_launches += 1,
            _ => self.tiers.interp_launches += 1,
        }

        // ---- launch: code push, eager copies, reference binding ----
        for (pos, (&cid, args)) in core_ids.iter().zip(bound).enumerate() {
            let mut spad =
                Scratchpad::new(cid, self.tech.local_store, self.tech.vm_footprint);
            // Kernel code image + launch frame travel to every core via the
            // direct path (the §5.1 "new data transfer mechanism").
            let code_bytes = (image_bytes + FRAME_HEADER_BYTES) as u64;
            let mut start = self.service.push_code(launch, code_bytes);
            self.stats.eager_bytes += code_bytes;

            let mut values: Vec<Value> = Vec::with_capacity(args.len());
            let mut binds: Vec<ExtBind> = Vec::new();
            let mut ext_lens: Vec<usize> = Vec::new();
            let mut eager_writebacks = Vec::new();

            for arg in args {
                match arg {
                    BoundArg::Float(v) => values.push(Value::Float(v)),
                    BoundArg::Int(v) => values.push(Value::Int(v)),
                    BoundArg::Values(vals) => {
                        // Small by-value array in the launch message: costs
                        // launch transfer time and on-core space.
                        let bytes = vals.len() * 4;
                        spad.alloc(bytes)?;
                        let done = self.service.push_code(launch, bytes as u64);
                        self.stats.eager_bytes += bytes as u64;
                        start = start.max(done);
                        values.push(Value::array(vals));
                    }
                    BoundArg::EagerCopy { dref, access } => {
                        let info = self.registry.info(dref)?;
                        let bytes = dref.bytes();
                        if spad.alloc(bytes).is_ok() {
                            // Cost level probed *before* the read: the read
                            // itself may pull the range into a fronting
                            // cache, and this launch must pay the cost of
                            // where the data was when it was asked for.
                            let lvl = self.registry.access_level(dref, 0, dref.len)?;
                            // Read into the reusable marshalling scratch
                            // (no per-argument Vec<f32> temporary), then
                            // widen into the Value's own storage.
                            self.scratch_m.clear();
                            self.scratch_m.resize(dref.len, 0.0);
                            self.registry.read(dref, Some(cid), 0, &mut self.scratch_m)?;
                            self.record_span(id, &dref, false);
                            let done =
                                self.service.eager_push(launch, lvl, bytes as u64);
                            self.stats.eager_bytes += bytes as u64;
                            start = start.max(done);
                            let arr: Vec<f64> =
                                self.scratch_m.iter().map(|&v| f64::from(v)).collect();
                            let val = Value::array(arr);
                            if access == Access::Mutable {
                                eager_writebacks
                                    .push((val.as_array().unwrap().clone(), dref));
                            }
                            values.push(val);
                        } else {
                            // ePython's overflow: data stays put, access
                            // degrades to by-reference on demand (§2.2).
                            spills += 1;
                            self.stats.spills += 1;
                            self.trace.emit(launch, cid, "spill", format!("{} B arg", bytes));
                            let slot = binds.len();
                            binds.push(ExtBind {
                                dref,
                                level: info.level,
                                access,
                                pf: None,
                            });
                            ext_lens.push(dref.len);
                            values.push(Value::External(slot));
                        }
                    }
                    BoundArg::External { dref, access, prefetch } => {
                        let info = self.registry.info(dref)?;
                        let slot = binds.len();
                        let pf = match prefetch {
                            Some(spec) => {
                                // The buffer is real on-core memory (§3.1's
                                // cost); reserve it.
                                spad.alloc(spec.buffer_bytes()).map_err(|_| {
                                    Error::ScratchpadExhausted {
                                        core: cid,
                                        requested: spec.buffer_bytes(),
                                        free: spad.free_bytes(),
                                    }
                                })?;
                                Some(PrefetchState::new(spec, dref.len)?)
                            }
                            None => None,
                        };
                        binds.push(ExtBind { dref, level: info.level, access, pf });
                        ext_lens.push(dref.len);
                        values.push(Value::External(slot));
                    }
                }
            }

            let mut vm = Interp::new(
                kernel.program.clone(),
                pos, // logical core index within this offload
                core_ids.len(),
                values,
                ext_lens,
            )?;
            if let Some(lp) = &lowered {
                vm.attach_lowered(lp.clone());
            }
            vm.set_fuel(options.fuel);
            let last_counters = vm.counters();
            let mut c = CoreRun {
                id: cid,
                launch: id,
                vm,
                clock: start,
                start,
                channel: Channel::new(cid),
                binds,
                status: Status::Fresh,
                stall: 0,
                result: None,
                finished_at: start,
                last_counters,
                eager_writebacks,
                autoconsume: Vec::new(),
                suspensions: 0,
            };
            // Restore this core from its checkpoint entry, replaying from
            // the captured suspension instead of from scratch. Cores
            // without an entry (never reached a checkpointable suspension)
            // restart from their freshly-marshalled arguments.
            if let Some(cc) = ck.as_ref().and_then(|k| k.cores.get(pos)).and_then(Option::as_ref)
            {
                let table = c.vm.restore(&cc.vm);
                debug_assert_eq!(cc.wb_roots.len(), c.eager_writebacks.len());
                for (k, &root) in cc.wb_roots.iter().enumerate() {
                    c.eager_writebacks[k].0 = Rc::clone(&table[root]);
                }
                c.last_counters = c.vm.counters();
                c.stall = cc.stall;
                for &(slot, cur) in &cc.pf_cursors {
                    if let Some(pf) = c.binds[slot].pf.as_mut() {
                        pf.seek(cur);
                    }
                }
                match &cc.resume {
                    ResumePoint::Read { slot, index } => {
                        c.status =
                            Status::Pending(Outcome::ExtRead { slot: *slot, index: *index });
                    }
                    ResumePoint::Write { slot, index, value } => {
                        c.status = Status::Pending(Outcome::ExtWrite {
                            slot: *slot,
                            index: *index,
                            value: *value,
                        });
                    }
                    ResumePoint::Done { result } => {
                        c.result = result.as_ref().map(Self::deep_copy_value);
                        c.status = Status::Done;
                        c.finished_at = start;
                    }
                }
                self.trace.emit(launch, cid, "restore", format!("{} B", cc.bytes));
            }
            cores.push(c);
            self.trace.emit(launch, cid, "launch", format!("start at {start}"));
        }

        // Warm the pre-fetch streams: the host issues the initial fill at
        // launch — before the cores even start — so transfer overlaps the
        // kernel prologue (§3.1's whole point). Issuing everything at
        // `launch` also keeps resource allocations in global time order
        // (the cores' staggered code-push start times come later).
        for c in cores.iter_mut() {
            if matches!(c.status, Status::Done) {
                continue; // restored-finished cores read nothing further
            }
            for slot in 0..c.binds.len() {
                if let Some(pf) = c.binds[slot].pf.as_ref() {
                    // For a fresh stream the cursor is 0 (the classic
                    // warm-up); a restored stream warms up at the
                    // checkpoint's cursor instead.
                    let idx = pf.cursor();
                    Self::issue_prefetch_spans_at(
                        &mut self.service,
                        &mut self.registry,
                        &mut self.stats,
                        c,
                        slot,
                        idx,
                        launch,
                    )?;
                }
            }
        }

        // Schedule the cores' first steps on the global event heap. For a
        // single active launch the heap degenerates to the classic
        // (candidate time, core position) min-structure — ties break on
        // core position, so the service order and every virtual time match
        // the pre-queue blocking scheduler exactly.
        for (pos, c) in cores.iter().enumerate() {
            if let Some(t) = Self::candidate(c) {
                self.events.push(Reverse((t, id, pos)));
            }
        }
        let l = &mut self.launches[li];
        l.cores = cores.into_iter().map(Some).collect();
        l.active = true;
        l.launched_at = launch;
        l.spills = spills;
        l.checkpoint = ck;
        // Restored-Done cores are not live; a launch whose cores all
        // finished before the fault completes immediately on restore.
        l.live = l
            .cores
            .iter()
            .flatten()
            .filter(|c| !matches!(c.status, Status::Done))
            .count();
        if l.live == 0 {
            self.complete(li)?;
        }
        Ok(())
    }

    /// Teardown for a launch whose cores are all `Done`: mutable-eager
    /// copy-backs, per-core reports, power accounting; park the result and
    /// release the cores (which may activate queued launches).
    fn complete(&mut self, li: usize) -> Result<()> {
        // A launch that was ever recovered (same-device retry) or resumed
        // from a migrated checkpoint counts as recovered once it actually
        // finishes.
        if self.launches[li].attempts > 0 || self.launches[li].options.restore.is_some() {
            self.fault_counters.recovered += 1;
        }
        let launch = self.launches[li].launched_at;
        let core_ids = self.launches[li].core_ids.clone();
        let spills = self.launches[li].spills;
        let tier = self.launches[li].options.tier;
        let heat_key = Rc::as_ptr(&self.launches[li].kernel.program) as usize;
        let mut cores: Vec<CoreRun> = self.launches[li]
            .cores
            .drain(..)
            .map(|c| c.expect("all cores parked at completion"))
            .collect();
        // Process in finish-time order so copy-back resource allocations
        // stay time-ordered among themselves; reports re-sorted after.
        cores.sort_by_key(|c| c.finished_at);
        let mut finish = launch;
        let mut reports = Vec::with_capacity(cores.len());
        let mut busy_total: Time = 0;
        for mut c in cores {
            // Mutable eager arguments copy back at completion (narrowed
            // through the reusable marshalling scratch — no temporary).
            for (arr, dref) in std::mem::take(&mut c.eager_writebacks) {
                self.scratch_m.clear();
                self.scratch_m.extend(arr.borrow().iter().map(|&v| v as f32));
                self.registry.write(dref, Some(c.id), 0, &self.scratch_m)?;
                self.record_span(c.launch, &dref, true);
                let done = self.service.service(c.finished_at, Level::Shared, dref.bytes() as u64);
                c.finished_at = done;
            }
            finish = finish.max(c.finished_at);
            busy_total += c.finished_at.saturating_sub(c.start).saturating_sub(c.stall);
            // Release occupancy at this core's own final finish time, so a
            // queued launch can start on it as early as possible.
            self.core_owner[c.id] = None;
            self.core_free[c.id] = c.finished_at;
            let counters = c.vm.counters();
            // Per-tier dispatch accounting, plus heat feedback so `Auto`
            // can promote a single hot kernel on its dispatch volume.
            match tier {
                TierChoice::Compiled => self.tiers.compiled_dispatches += counters.dispatches,
                _ => self.tiers.interp_dispatches += counters.dispatches,
            }
            self.tier_heat.entry(heat_key).or_default().dispatches += counters.dispatches;
            reports.push(CoreReport {
                core: c.id,
                value: c.result.take().unwrap_or(Value::None),
                finished_at: c.finished_at,
                stall: c.stall,
                counters,
                requests: c.channel.issued(),
                peak_cells: c.channel.peak_occupancy(),
                cell_stalls: c.channel.stalls(),
            });
        }
        reports.sort_by_key(|r| {
            core_ids.iter().position(|&id| id == r.core).unwrap_or(usize::MAX)
        });
        let duration = finish.saturating_sub(launch).max(1);
        let utilization =
            busy_total as f64 / (duration as f64 * self.tech.cores as f64);
        // `now` is the completion watermark (monotone even when launches
        // finish out of submission order); power integrates up to it.
        // With overlapped launches this attributes each launch's average
        // utilization to the watermark-to-finish tail only — an
        // energy-model approximation (virtual times are exact; sequential
        // runs are unaffected, where watermark == previous finish).
        self.now = self.now.max(finish);
        self.power.advance(self.now, utilization.min(1.0));
        self.stats.offloads += 1;
        let id = self.launches[li].id;
        self.launches[li].outcome = Some(Ok(OffloadResult {
            reports,
            launched_at: launch,
            finished_at: finish,
            spills,
        }));
        // Satisfy dependency edges before the reservation scan so
        // newly-unblocked launches activate in the same pass.
        self.resolve_deps(id, finish);
        self.reserve_ready();
        Ok(())
    }

    /// A core's candidate time: when it next needs service (`None` once
    /// done). The scheduler always services the minimum candidate.
    fn candidate(c: &CoreRun) -> Option<Time> {
        match &c.status {
            Status::Fresh | Status::Pending(_) => Some(c.clock),
            Status::Waiting { ready_at, .. } => Some((*ready_at).max(c.clock)),
            Status::Retry { at, .. } => Some((*at).max(c.clock)),
            Status::Done => None,
        }
    }

    /// Service one core at its candidate time.
    fn step_core(&mut self, c: &mut CoreRun, cand: Time) -> Result<()> {
        match std::mem::replace(&mut c.status, Status::Fresh) {
            Status::Fresh => {
                c.clock = c.clock.max(cand);
                let out = c.vm.run()?;
                self.charge_vm(c);
                c.status = Status::Pending(out);
            }
            Status::Pending(out) => {
                c.clock = c.clock.max(cand);
                self.service_outcome(c, out)?;
            }
            Status::Waiting { handle, ctx, ready_at } => {
                c.stall += ready_at.saturating_sub(c.clock);
                c.clock = c.clock.max(ready_at);
                let data = c.channel.consume(handle, c.clock)?;
                self.stats.requests += 1;
                match ctx {
                    WaitCtx::OnDemandRead => {
                        let v = f64::from(data[0]);
                        let out = c.vm.resume(Value::Float(v))?;
                        self.charge_vm(c);
                        c.status = Status::Pending(out);
                    }
                    WaitCtx::WriteAck => {
                        let out = c.vm.resume(Value::None)?;
                        self.charge_vm(c);
                        c.status = Status::Pending(out);
                    }
                    WaitCtx::PrefetchRead { slot, index } => {
                        if let Some(pf) = c.binds[slot].pf.as_mut() {
                            pf.on_arrival(handle, &data);
                        }
                        // Re-enter the read path with the data landed.
                        self.service_outcome(c, Outcome::ExtRead { slot, index })?;
                    }
                }
            }
            Status::Retry { outcome, at } => {
                c.stall += at.saturating_sub(c.clock);
                c.clock = c.clock.max(at);
                self.harvest(c);
                self.service_outcome(c, outcome)?;
            }
            Status::Done => unreachable!("done cores are not scheduled"),
        }
        Ok(())
    }

    /// Convert the VM's cost delta since the last call into core time.
    fn charge_vm(&self, c: &mut CoreRun) {
        let now = c.vm.counters();
        let dd = now.dispatches - c.last_counters.dispatches;
        let df = now.flops - c.last_counters.flops;
        c.last_counters = now;
        c.clock += self.compute.dispatch(dd) + self.compute.compiled_flops(df);
    }

    /// Consume arrived responses (pre-fetch data, write acks) at `c.clock`.
    /// Consume-only and core-local (the channel belongs to this core), so
    /// it is safe to call from the inline fast path at any point; calling
    /// it twice at the same clock is a no-op the second time.
    fn harvest(&mut self, c: &mut CoreRun) {
        let clock = c.clock;
        let CoreRun { autoconsume, channel, binds, .. } = c;
        let mut consumed = 0u64;
        // Write acks: consume silently.
        autoconsume.retain(|&h| {
            if channel.ready(h, clock).unwrap_or(false) {
                let _ = channel.consume(h, clock);
                consumed += 1;
                false
            } else {
                true
            }
        });
        // Pre-fetch arrivals, scanned in place (perf pass #4: this runs
        // per element read — no per-call Vec of handles). `on_arrival`
        // removes the entry at the scan position, so only advance on a
        // non-ready span.
        for b in binds.iter_mut() {
            if let Some(pf) = b.pf.as_mut() {
                let mut i = 0;
                while i < pf.inflight().len() {
                    let h = pf.inflight()[i].handle;
                    if channel.ready(h, clock).unwrap_or(false) {
                        if let Ok(data) = channel.consume(h, clock) {
                            consumed += 1;
                            pf.on_arrival(h, &data);
                            continue;
                        }
                    }
                    i += 1;
                }
            }
        }
        self.stats.requests += consumed;
    }

    /// Issue as many pending pre-fetch spans as cells allow for `slot`,
    /// reading stream position `idx`, at the core's current clock.
    fn issue_prefetch_spans(
        service: &mut HostService,
        registry: &mut MemRegistry,
        stats: &mut EngineStats,
        c: &mut CoreRun,
        slot: usize,
        idx: usize,
    ) -> Result<usize> {
        let at = c.clock;
        Self::issue_prefetch_spans_at(service, registry, stats, c, slot, idx, at)
    }

    /// As [`Self::issue_prefetch_spans`] but at an explicit issue time
    /// (the launch-time warm-up path).
    fn issue_prefetch_spans_at(
        service: &mut HostService,
        registry: &mut MemRegistry,
        stats: &mut EngineStats,
        c: &mut CoreRun,
        slot: usize,
        idx: usize,
        at: Time,
    ) -> Result<usize> {
        let b = &mut c.binds[slot];
        let Some(pf) = b.pf.as_mut() else { return Ok(0) };
        let spans = pf.spans_to_fetch(idx);
        let mut issued = 0;
        for (start, len) in spans {
            let req = Request {
                core: c.id,
                kind: RequestKind::Read { dref: b.dref, off: start, len },
                issued_at: at,
            };
            let wire = req.kind.wire_bytes();
            match c.channel.issue(req)? {
                Some(h) => {
                    // Probe the servicing level before the read: the read
                    // refills a fronting cache on miss, and the cost must
                    // reflect pre-access residency.
                    let lvl = registry.access_level(b.dref, start, len)?;
                    let mut data = vec![0.0f32; len];
                    registry.read(b.dref, Some(c.id), start, &mut data)?;
                    let ready = service.service(at, lvl, wire);
                    c.channel.begin_service(h)?;
                    c.channel.complete(h, ready, data)?;
                    pf.on_issued(h, start, len);
                    issued += 1;
                }
                None => break, // backpressure: stop topping up
            }
        }
        let _ = stats;
        Ok(issued)
    }

    /// Service a VM outcome at `c.clock` (the global minimum).
    fn service_outcome(&mut self, c: &mut CoreRun, out: Outcome) -> Result<()> {
        match out {
            Outcome::Done(v) => {
                // Result copy-back (the per-core return list of §2.2).
                let bytes = match &v {
                    Value::Array(a) => a.borrow().len() * 4,
                    _ => 8,
                };
                let done = self.service.service(
                    c.clock,
                    Level::Shared,
                    (bytes + FRAME_HEADER_BYTES) as u64,
                );
                self.stats.requests += 1;
                c.finished_at = done;
                c.result = Some(v);
                c.status = Status::Done;
                self.trace.emit(done, c.id, "done", "");
            }
            Outcome::ExtRead { mut slot, mut index } => {
                // (Recording, not servicing: the VM only emits ExtRead
                // after its own bounds check, so the request *is* the
                // access for soundness purposes; a retried outcome may
                // record twice, which the ⊆-check tolerates.)
                let dref = c.binds[slot].dref;
                self.record_access(c.launch, &dref, index, false);
                // Inline fast path: consume a run of pure pre-fetch hits
                // without a scheduler round trip per element. Legal only
                // while no shared resource is touched — the buffer hit is
                // core-local, `harvest` is consume-only, and the VM
                // advance moves only this core's clock (module docs). The
                // moment the next read would issue a span, miss, or leave
                // the pre-fetch path, hand the outcome back to the
                // scheduler so it is serviced in global time order.
                if self.fast_path {
                    let mut advanced = false;
                    while c.binds[slot].level != Level::CoreLocal && c.binds[slot].pf.is_some()
                    {
                        self.harvest(c);
                        let pf = c.binds[slot].pf.as_ref().expect("checked");
                        let Some(v) = pf.peek_hit(index) else { break };
                        if pf.wants_fetch(index) {
                            break;
                        }
                        c.binds[slot].pf.as_mut().expect("checked").note_hit();
                        let out = c.vm.resume(Value::Float(v))?;
                        self.charge_vm(c);
                        advanced = true;
                        match out {
                            Outcome::ExtRead { slot: s, index: i } => {
                                slot = s;
                                index = i;
                                let dref = c.binds[slot].dref;
                                self.record_access(c.launch, &dref, index, false);
                            }
                            other => {
                                c.status = Status::Pending(other);
                                return Ok(());
                            }
                        }
                    }
                    if advanced {
                        // The VM moved past this core's original candidate
                        // time; requeue the unservable read for global
                        // ordering instead of servicing it late here.
                        c.status = Status::Pending(Outcome::ExtRead { slot, index });
                        return Ok(());
                    }
                }
                // Microcore-kind data is *in this core's local store*: the
                // reference decodes to a local load (§3.2) — no channel.
                if c.binds[slot].level == Level::CoreLocal {
                    let b = &c.binds[slot];
                    let mut data = [0.0f32];
                    self.registry.read(b.dref, Some(c.id), index, &mut data)?;
                    c.clock += self.compute.dispatch(4);
                    let out = c.vm.resume(Value::Float(f64::from(data[0])))?;
                    self.charge_vm(c);
                    c.status = Status::Pending(out);
                    return Ok(());
                }
                self.harvest(c);
                if c.binds[slot].pf.is_some() {
                    self.prefetch_read(c, slot, index)?;
                } else {
                    self.ondemand_read(c, slot, index)?;
                }
            }
            Outcome::ExtWrite { slot, index, value } => {
                if c.binds[slot].level == Level::CoreLocal {
                    let b = &c.binds[slot];
                    if b.access == Access::ReadOnly {
                        return Err(Error::Coordinator(
                            "write to read-only reference argument".into(),
                        ));
                    }
                    let dref = b.dref;
                    self.registry.write(dref, Some(c.id), index, &[value as f32])?;
                    self.record_access(c.launch, &dref, index, true);
                    c.clock += self.compute.dispatch(4);
                    let out = c.vm.resume(Value::None)?;
                    self.charge_vm(c);
                    c.status = Status::Pending(out);
                    return Ok(());
                }
                self.ext_write(c, slot, index, value)?;
            }
            Outcome::Tensor(top) => {
                let v = self.handle_tensor(c, top)?;
                let out = c.vm.resume(v)?;
                self.charge_vm(c);
                c.status = Status::Pending(out);
            }
        }
        Ok(())
    }

    fn ondemand_read(&mut self, c: &mut CoreRun, slot: usize, index: usize) -> Result<()> {
        let b = &c.binds[slot];
        let req = Request {
            core: c.id,
            kind: RequestKind::Read { dref: b.dref, off: index, len: 1 },
            issued_at: c.clock,
        };
        let wire = req.kind.wire_bytes();
        match c.channel.issue(req)? {
            Some(h) => {
                // Pre-access residency decides the cost (see module docs);
                // the read below may refill a fronting cache.
                let lvl = self.registry.access_level(b.dref, index, 1)?;
                let mut data = [0.0f32];
                self.registry.read(b.dref, Some(c.id), index, &mut data)?;
                let ready = self.service.service(c.clock, lvl, wire);
                c.channel.begin_service(h)?;
                c.channel.complete(h, ready, data.to_vec())?;
                c.status = Status::Waiting { handle: h, ctx: WaitCtx::OnDemandRead, ready_at: ready };
            }
            None => {
                let at = c.channel.earliest_ready_at().ok_or_else(|| {
                    Error::Channel("channel full with no inflight completions".into())
                })?;
                c.status =
                    Status::Retry { outcome: Outcome::ExtRead { slot, index }, at };
            }
        }
        Ok(())
    }

    fn prefetch_read(&mut self, c: &mut CoreRun, slot: usize, index: usize) -> Result<()> {
        loop {
            let plan = c.binds[slot].pf.as_mut().unwrap().plan_read(index);
            match plan {
                ReadPlan::Hit(v) => {
                    // Top up the stream, then continue the VM.
                    Self::issue_prefetch_spans(
                        &mut self.service,
                        &mut self.registry,
                        &mut self.stats,
                        c,
                        slot,
                        index,
                    )?;
                    let out = c.vm.resume(Value::Float(v))?;
                    self.charge_vm(c);
                    c.status = Status::Pending(out);
                    return Ok(());
                }
                ReadPlan::WaitInflight(h) => {
                    let ready_at = c
                        .channel
                        .ready_at(h)?
                        .ok_or_else(|| Error::Channel("inflight cell not serviced".into()))?;
                    c.status = Status::Waiting {
                        handle: h,
                        ctx: WaitCtx::PrefetchRead { slot, index },
                        ready_at,
                    };
                    return Ok(());
                }
                ReadPlan::Miss => {
                    let issued = Self::issue_prefetch_spans(
                        &mut self.service,
                        &mut self.registry,
                        &mut self.stats,
                        c,
                        slot,
                        index,
                    )?;
                    if issued == 0 {
                        let at = c.channel.earliest_ready_at().ok_or_else(|| {
                            Error::Channel("channel full with no inflight completions".into())
                        })?;
                        c.status =
                            Status::Retry { outcome: Outcome::ExtRead { slot, index }, at };
                        return Ok(());
                    }
                    // Loop: the plan will now find the inflight span.
                }
            }
        }
    }

    fn ext_write(&mut self, c: &mut CoreRun, slot: usize, index: usize, value: f64) -> Result<()> {
        let b = &mut c.binds[slot];
        if b.access == Access::ReadOnly {
            return Err(Error::Coordinator(format!(
                "write to read-only reference argument (slot {slot}); \
                 declare it mutable in the access modifier"
            )));
        }
        // §3.3: write updates any local copy AND writes through.
        if let Some(pf) = b.pf.as_mut() {
            pf.on_write(index, value as f32);
        }
        let req = Request {
            core: c.id,
            kind: RequestKind::Write { dref: b.dref, off: index, data: vec![value as f32] },
            issued_at: c.clock,
        };
        let wire = req.kind.wire_bytes();
        let prefetched = b.pf.is_some();
        match c.channel.issue(req)? {
            Some(h) => {
                // Write-back caches absorb writes to resident segments at
                // shared-window cost; probe before the write allocates.
                let lvl = self.registry.access_level(b.dref, index, 1)?;
                // Atomic per-element write applied in service order.
                self.registry.write(b.dref, Some(c.id), index, &[value as f32])?;
                self.record_access(c.launch, &b.dref, index, true);
                let ready = self.service.service(c.clock, lvl, wire);
                c.channel.begin_service(h)?;
                c.channel.complete(h, ready, Vec::new())?;
                if prefetched {
                    // Write-through is non-blocking under pre-fetch;
                    // ordering within the core is preserved by FCFS
                    // service.
                    c.autoconsume.push(h);
                    let out = c.vm.resume(Value::None)?;
                    self.charge_vm(c);
                    c.status = Status::Pending(out);
                } else {
                    // On-demand writes block (§3.1 default).
                    c.status =
                        Status::Waiting { handle: h, ctx: WaitCtx::WriteAck, ready_at: ready };
                }
            }
            None => {
                let at = c.channel.earliest_ready_at().ok_or_else(|| {
                    Error::Channel("channel full with no inflight completions".into())
                })?;
                c.status =
                    Status::Retry { outcome: Outcome::ExtWrite { slot, index, value }, at };
            }
        }
        Ok(())
    }

    // ---- tensor builtins -------------------------------------------------

    /// Gather `h` rows of `len` columns at column `off` from a row-major
    /// `[h, t]` external variable into `out` (reused scratch).
    fn gather_rows_into(
        registry: &MemRegistry,
        out: &mut Vec<f32>,
        dref: DataRef,
        core: usize,
        h: usize,
        t: usize,
        off: usize,
        len: usize,
    ) -> Result<()> {
        out.clear();
        out.resize(h * len, 0.0);
        for r in 0..h {
            registry.read(dref, Some(core), r * t + off, &mut out[r * len..(r + 1) * len])?;
        }
        Ok(())
    }

    fn scatter_rows(
        &mut self,
        dref: DataRef,
        core: usize,
        h: usize,
        t: usize,
        off: usize,
        len: usize,
        data: &[f32],
    ) -> Result<()> {
        for r in 0..h {
            self.registry.write(dref, Some(core), r * t + off, &data[r * len..(r + 1) * len])?;
        }
        Ok(())
    }

    /// Charge a bulk device-initiated transfer of `bytes` from `level`.
    /// Device-addressable levels use DMA (link only); non-addressable
    /// levels must be shuttled by the host service.
    fn bulk_transfer(&mut self, at: Time, level: Level, bytes: u64) -> Time {
        self.stats.dma_bytes += bytes;
        if self.service.hierarchy().addressable(level) {
            self.service.dma(at, level, bytes)
        } else {
            self.service.service(at, level, bytes)
        }
    }

    fn ext_of(&self, c: &CoreRun, v: &Value) -> Option<(DataRef, Level)> {
        match v {
            Value::External(slot) => {
                let b = &c.binds[*slot];
                Some((b.dref, b.level))
            }
            _ => None,
        }
    }

    fn handle_tensor(&mut self, c: &mut CoreRun, top: TensorOp) -> Result<Value> {
        self.stats.tensor_ops += 1;
        match top.builtin {
            Builtin::Dot => {
                let a = top.args[0].to_f32_vec()?;
                let b = top.args[1].to_f32_vec()?;
                if a.len() != b.len() {
                    return Err(Error::Vm("dot: length mismatch".into()));
                }
                let (val, flops) = match &self.exec {
                    Some(ex) => ex.dot(&a, &b)?,
                    None => {
                        self.stats.native_fallbacks += 1;
                        let s: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
                        (s, 2 * a.len() as u64)
                    }
                };
                c.clock += self.compute.compiled_flops(flops);
                Ok(Value::Float(f64::from(val)))
            }
            Builtin::FwdAccum => {
                // fwd_accum(w, off, len, xbuf, acc)
                let off = top.args[1].as_index()?;
                let len = top.args[2].as_index()?;
                let x = top.args[3].to_f32_vec()?;
                let acc = top.args[4].to_f32_vec()?;
                if x.len() != len {
                    return Err(Error::Vm(format!(
                        "fwd_accum: xbuf has {} elems, len says {len}",
                        x.len()
                    )));
                }
                let h = acc.len();
                let mut w = std::mem::take(&mut self.scratch_a);
                match self.ext_of(c, &top.args[0]) {
                    Some((dref, level)) => {
                        let t = dref.len / h;
                        Self::gather_rows_into(&self.registry, &mut w, dref, c.id, h, t, off, len)?;
                        self.record_span(c.launch, &dref, false);
                        let done = self.bulk_transfer(c.clock, level, (h * len * 4) as u64);
                        c.clock = done;
                    }
                    None => {
                        // W held locally (unusual but allowed): slice it.
                        let full = top.args[0].to_f32_vec()?;
                        let t = full.len() / h;
                        w.clear();
                        w.resize(h * len, 0.0);
                        for r in 0..h {
                            w[r * len..(r + 1) * len]
                                .copy_from_slice(&full[r * t + off..r * t + off + len]);
                        }
                    }
                };
                let res = match &self.exec {
                    Some(ex) => ex.fwd_accum(&w, &x, &acc)?,
                    None => {
                        self.stats.native_fallbacks += 1;
                        let mut out = acc.clone();
                        for (r, o) in out.iter_mut().enumerate() {
                            let mut s = 0.0f32;
                            for j in 0..len {
                                s += w[r * len + j] * x[j];
                            }
                            *o += s;
                        }
                        (out, (2 * h * len) as u64)
                    }
                };
                self.scratch_a = w;
                let (out, flops) = res;
                c.clock += self.compute.compiled_flops(flops);
                Ok(Value::array(out.into_iter().map(f64::from).collect()))
            }
            Builtin::GradTile => {
                // grad_tile(dh, xbuf, g, off)
                let dh = top.args[0].to_f32_vec()?;
                let x = top.args[1].to_f32_vec()?;
                let off = top.args[3].as_index()?;
                let h = dh.len();
                let len = x.len();
                let (gref, glevel) = self.ext_of(c, &top.args[2]).ok_or_else(|| {
                    Error::Vm("grad_tile: g must be a reference argument".into())
                })?;
                let t = gref.len / h;
                let mut gtile = std::mem::take(&mut self.scratch_a);
                Self::gather_rows_into(&self.registry, &mut gtile, gref, c.id, h, t, off, len)?;
                self.record_span(c.launch, &gref, false);
                let bytes = (h * len * 4) as u64;
                let read_done = self.bulk_transfer(c.clock, glevel, bytes);
                let (out, flops) = match &self.exec {
                    Some(ex) => ex.grad_shard(&dh, &x, &gtile)?,
                    None => {
                        self.stats.native_fallbacks += 1;
                        let mut out = gtile.clone();
                        for r in 0..h {
                            for j in 0..len {
                                out[r * len + j] += dh[r] * x[j];
                            }
                        }
                        (out, (2 * h * len) as u64)
                    }
                };
                let compute_done = read_done + self.compute.compiled_flops(flops);
                self.scatter_rows(gref, c.id, h, t, off, len, &out)?;
                self.record_span(c.launch, &gref, true);
                self.scratch_a = gtile;
                c.clock = self.bulk_transfer(compute_done, glevel, bytes);
                Ok(Value::Int(0))
            }
            Builtin::UpdateTile => {
                // update_tile(w, g, lr, off, len)
                let lr = top.args[2].as_f64()? as f32;
                let off = top.args[3].as_index()?;
                let len = top.args[4].as_index()?;
                let h = self.hidden;
                let (wref, wlevel) = self.ext_of(c, &top.args[0]).ok_or_else(|| {
                    Error::Vm("update_tile: w must be a reference argument".into())
                })?;
                let (gref, glevel) = self.ext_of(c, &top.args[1]).ok_or_else(|| {
                    Error::Vm("update_tile: g must be a reference argument".into())
                })?;
                let t = wref.len / h;
                let mut wtile = std::mem::take(&mut self.scratch_a);
                let mut gtile = std::mem::take(&mut self.scratch_b);
                Self::gather_rows_into(&self.registry, &mut wtile, wref, c.id, h, t, off, len)?;
                Self::gather_rows_into(&self.registry, &mut gtile, gref, c.id, h, t, off, len)?;
                self.record_span(c.launch, &wref, false);
                self.record_span(c.launch, &gref, false);
                let bytes = (h * len * 4) as u64;
                let r1 = self.bulk_transfer(c.clock, wlevel, bytes);
                let r2 = self.bulk_transfer(r1, glevel, bytes);
                let (out, flops) = match &self.exec {
                    Some(ex) => ex.update_shard(&wtile, &gtile, lr)?,
                    None => {
                        self.stats.native_fallbacks += 1;
                        let out: Vec<f32> =
                            wtile.iter().zip(&gtile).map(|(w, g)| w - lr * g).collect();
                        (out, (2 * h * len) as u64)
                    }
                };
                let compute_done = r2 + self.compute.compiled_flops(flops);
                self.scatter_rows(wref, c.id, h, t, off, len, &out)?;
                self.record_span(c.launch, &wref, true);
                self.scratch_a = wtile;
                self.scratch_b = gtile;
                c.clock = self.bulk_transfer(compute_done, wlevel, bytes);
                Ok(Value::Int(0))
            }
            other => Err(Error::Vm(format!("{other:?} is not a tensor builtin"))),
        }
    }
}
