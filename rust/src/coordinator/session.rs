//! The user-facing session: the ePython module surface, in Rust.
//!
//! A [`Session`] owns one simulated device plus the host-side runtime:
//! memory kinds, kernel registry, offload engine, and (optionally) the
//! PJRT executor for tensor builtins. Its API mirrors the paper's Python
//! surface:
//!
//! | paper (Python)                         | here                                      |
//! |----------------------------------------|-------------------------------------------|
//! | `memkind.Host(types.int, 1000)`        | [`Session::alloc`] + [`MemSpec::host`]    |
//! | `memkind.Shared(...)`                  | [`Session::alloc`] + [`MemSpec::shared`]  |
//! | `memkind.Microcore(...)`               | [`Session::alloc`] + [`MemSpec::microcore`] |
//! | `@offload` + call                      | [`Session::compile_kernel`] + [`Session::launch`] |
//! | `prefetch={...}` decorator argument    | [`ArgSpec::with_prefetch`] / [`LaunchBuilder::prefetch`] |
//! | `define_on_device` / `copy_to_device` / `copy_from_device` | [`Session::define_on_device`] / [`Session::copy_to_device`] / [`Session::copy_from_device`] |
//!
//! Changing where data lives is one call-site change — swap the
//! [`MemSpec`] constructor — with everything downstream (reference
//! decoding, transfer costs, host staging) following from the kind, as
//! §3.2 prescribes.
//!
//! ## Asynchronous launches and the launch graph
//!
//! Kernel invocation is an asynchronous *launch*:
//!
//! ```ignore
//! let h = sess.launch(&kernel).args(&[ArgSpec::sharded(a)]).submit()?;
//! // ... submit more launches; the engine orders them by data flow ...
//! let result = h.wait(&mut sess)?;          // or sess.wait_all()?
//! ```
//!
//! Submitted launches form a *launch graph*: the builder records each
//! argument's read/write window, and the engine adds a dependency edge
//! wherever two in-flight launches touch overlapping data with at least
//! one writer (plus any explicit [`LaunchBuilder::after`] edges). A
//! dependent chain submitted with **no intervening waits** therefore
//! executes bit-identically to the blocking sequence, while launches
//! with no edges between them pipeline on the shared virtual timeline
//! (see [`super::engine`]'s module docs). Submit-then-wait reproduces
//! the classic blocking collective bit-for-bit. `handle.wait(&mut sess)`
//! takes the session explicitly — the handle itself is a plain `Copy`
//! ticket, so any number can be in flight without aliasing the session
//! borrow. [`Session::queue_stats`] tells launches *blocked on edges*
//! apart from launches queued on core contention.
//!
//! The pre-0.3 surface (the `alloc_*` method grid and the blocking
//! `offload`/`offload_named`) was removed in 0.4 after its one-release
//! deprecation window; use [`Session::alloc`] + [`MemSpec`] and the
//! launch builder.

use crate::analysis::{check_kernel_budget, Diagnostic, GraphReport, VerifyLevel};
use crate::device::Technology;
use crate::error::{Error, Result};
use crate::memory::{
    CacheSpec, DataRef, FileKind, HostKind, MemInit, MemKind, MemPlace, MemSpec, MicrocoreKind,
    ProceduralKind, SharedCacheKind, SharedKind, SinkKind,
};
use crate::runtime::{ModelExecutor, PjrtContext};
use crate::sim::{FaultCounters, FaultPlan, Time};
use crate::vm::Value;

use super::engine::{Engine, EngineStats, LaunchId, LaunchStatus, TierCounters};
use super::marshal::{bind, ArgSpec};
use super::offload::{Kernel, KernelRegistry, OffloadOptions, OffloadResult};
use super::prefetch::PrefetchSpec;
use super::{TierChoice, TransferMode};

/// Builder for [`Session`].
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    tech: Technology,
    artifacts_dir: Option<String>,
    service_threads: usize,
    seed: u64,
    trace_capacity: Option<usize>,
    faults: Option<FaultPlan>,
    verify: VerifyLevel,
    tier: TierChoice,
}

impl SessionBuilder {
    /// Start building a session for a technology preset.
    pub fn new(tech: Technology) -> Self {
        SessionBuilder {
            tech,
            artifacts_dir: None,
            service_threads: 1,
            seed: 42,
            trace_capacity: None,
            faults: None,
            verify: VerifyLevel::Off,
            tier: TierChoice::Interp,
        }
    }

    /// Set the session-wide default execution tier
    /// ([`TierChoice::Interp`] unless overridden — tier choice never
    /// changes kernel results, only host-side dispatch cost; see
    /// [`crate::vm::tier`]). Individual launches override it with
    /// [`LaunchBuilder::tier`].
    pub fn tier(mut self, tier: TierChoice) -> Self {
        self.tier = tier;
        self
    }

    /// Set the static-verification level applied at every submit
    /// ([`VerifyLevel::Off`] by default — zero analysis overhead). At
    /// `Warn`, the engine analyzes each launch's bytecode and collects
    /// diagnostics ([`Session::take_diagnostics`]) without changing any
    /// behavior; at `Strict`, an `Error`-severity finding (a definite
    /// under-declared flow) rejects the launch at submit with
    /// [`crate::error::Error::Analysis`]. See [`crate::analysis`].
    pub fn verify(mut self, level: VerifyLevel) -> Self {
        self.verify = level;
        self
    }

    /// Attach AOT artifacts (enables PJRT-backed tensor builtins).
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Host service threads (§4 models one dedicated thread by default).
    pub fn service_threads(mut self, n: usize) -> Self {
        self.service_threads = n.max(1);
        self
    }

    /// Deterministic seed for service jitter and synthetic content.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Record a bounded event trace.
    pub fn trace(mut self, capacity: usize) -> Self {
        self.trace_capacity = Some(capacity);
        self
    }

    /// Install a seeded fault schedule ([`FaultPlan`]) — transient core
    /// faults, transfer corruption and permanent device loss, delivered
    /// deterministically on the virtual timeline. Pair with the launch
    /// builder's `.retry(n)`/`.backoff(t)` to recover from them; without
    /// a budget the first fault fails the launch (today's fail-fast).
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Construct the session.
    pub fn build(self) -> Result<Session> {
        let exec = match &self.artifacts_dir {
            Some(dir) => Some(ModelExecutor::new(PjrtContext::new(dir)?)),
            None => None,
        };
        let mut engine = Engine::new(self.tech.clone(), self.service_threads, self.seed, exec);
        if let Some(cap) = self.trace_capacity {
            engine.enable_trace(cap);
        }
        if let Some(plan) = self.faults {
            engine.install_faults(plan);
        }
        engine.set_verify(self.verify);
        Ok(Session {
            tech: self.tech,
            engine,
            kernels: KernelRegistry::new(),
            default_tier: self.tier,
        })
    }
}

/// A live offload session against one simulated micro-core device.
#[derive(Debug)]
pub struct Session {
    tech: Technology,
    engine: Engine,
    kernels: KernelRegistry,
    /// Execution tier seeded into every launch builder
    /// ([`SessionBuilder::tier`]; per-launch [`LaunchBuilder::tier`]
    /// overrides it).
    default_tier: TierChoice,
}

// SAFETY: a `Session` is one closed ownership island. `SessionBuilder::
// build` constructs the engine, registry, kernel table and executor cache
// from scratch — every `Rc`/`RefCell` reachable from a session was created
// inside it, and no API hands an `Rc` from one session to another (group
// buffers are *replicated* per device, kernels are compiled per device,
// cross-device data moves by value through host staging). Confining a
// `&mut Session` to one worker thread under a joined scope therefore
// cannot race any reference count or cell; see `runtime::parallel`.
unsafe impl crate::runtime::parallel::IsolatedIsland for Session {}

impl Session {
    /// Builder entry point.
    pub fn builder(tech: Technology) -> SessionBuilder {
        SessionBuilder::new(tech)
    }

    /// The technology preset.
    pub fn tech(&self) -> &Technology {
        &self.tech
    }

    /// The engine (stats, trace, service knobs).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutable engine access.
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Engine statistics snapshot.
    pub fn stats(&self) -> EngineStats {
        self.engine.stats()
    }

    /// Fault/recovery accounting (all-zero without a fault plan).
    pub fn fault_counters(&self) -> FaultCounters {
        self.engine.fault_counters()
    }

    /// Per-tier execution accounting: launches and dispatches retired on
    /// the interpreter vs the compiled linear-IR tier, plus the `Auto`
    /// selector's promotion/demotion decisions.
    pub fn tier_counters(&self) -> TierCounters {
        self.engine.tier_counters()
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.engine.now()
    }

    /// The device's busy horizon: the latest virtual time any core is
    /// reserved through ([`Engine::core_horizon`]). Never below
    /// [`Session::now`], but can exceed it after a failed launch —
    /// failure releases cores at their stamped progress without ever
    /// completing, so the completion watermark `now` lags the true
    /// busy-until. Schedulers placing future work (e.g. the fleet's
    /// slot watermark) should use this, not `now`.
    pub fn core_horizon(&self) -> Time {
        self.engine.core_horizon()
    }

    // ---- memory allocation (§3.2) ---------------------------------------

    /// Allocate a variable from a declarative [`MemSpec`] — the single
    /// entry point for every *place × initializer* combination:
    ///
    /// ```ignore
    /// let a = sess.alloc(MemSpec::host("a").from(&data))?;
    /// let b = sess.alloc(MemSpec::shared("b").zeroed(1024))?;
    /// let c = sess.alloc(MemSpec::cached("c", cache_spec).from(&data))?;
    /// let d = sess.alloc(MemSpec::microcore("d").zeroed(16))?;
    /// ```
    ///
    /// Placement constraints are enforced here: shared-window allocations
    /// are bounded by the technology's window, microcore replicas by the
    /// per-core user store, cache budgets by the window. A `Microcore`
    /// spec with [`MemSpec::from`] data broadcasts the contents into every
    /// core's replica (the `copy_to_device` semantics).
    pub fn alloc(&mut self, spec: MemSpec) -> Result<DataRef> {
        let (name, place, init) = spec.into_parts();
        let len = init.len();
        if len == 0 {
            // Guard the builder's default initializer: a bare
            // `MemSpec::host("a")` would otherwise silently allocate an
            // empty variable and every downstream kernel loop would be a
            // no-op.
            return Err(Error::Memory(format!(
                "allocation '{name}' has no elements — initialize the MemSpec \
                 with .zeroed(len) or .from(data)"
            )));
        }
        match place {
            MemPlace::Host => {
                let kind = match init {
                    MemInit::Data(v) => HostKind::from_vec(v),
                    MemInit::Zeroed(n) => HostKind::zeroed(n),
                };
                Ok(self.engine.registry_mut().register(name, Box::new(kind)))
            }
            MemPlace::Shared => {
                let kind = match init {
                    MemInit::Data(v) => SharedKind::from_vec(v, self.tech.shared_window)?,
                    MemInit::Zeroed(n) => SharedKind::zeroed(n, self.tech.shared_window)?,
                };
                Ok(self.engine.registry_mut().register(name, Box::new(kind)))
            }
            MemPlace::Microcore => {
                let bytes = len * 4;
                if bytes > self.tech.user_store() {
                    return Err(Error::ScratchpadExhausted {
                        core: 0,
                        requested: bytes,
                        free: self.tech.user_store(),
                    });
                }
                let dref = self
                    .engine
                    .registry_mut()
                    .register(name, Box::new(MicrocoreKind::zeroed(self.tech.cores, len)));
                if let MemInit::Data(v) = init {
                    self.engine.registry_mut().write(dref, None, 0, &v)?;
                }
                Ok(dref)
            }
            MemPlace::Cached(cache) => {
                let kind = match init {
                    MemInit::Data(v) => HostKind::from_vec(v),
                    MemInit::Zeroed(n) => HostKind::zeroed(n),
                };
                self.alloc_cached_kind(&name, Box::new(kind), cache)
            }
            MemPlace::Procedural { seed, scale } => match init {
                MemInit::Zeroed(n) => Ok(self
                    .engine
                    .registry_mut()
                    .register(name, Box::new(ProceduralKind::new(seed, n, scale)))),
                MemInit::Data(_) => Err(Error::Memory(
                    "procedural variables generate their content; size them with .zeroed(len)"
                        .into(),
                )),
            },
            MemPlace::Sink => match init {
                MemInit::Zeroed(n) => {
                    Ok(self.engine.registry_mut().register(name, Box::new(SinkKind::new(n))))
                }
                MemInit::Data(_) => Err(Error::Memory(
                    "sink variables discard their content; size them with .zeroed(len)".into(),
                )),
            },
            MemPlace::File(path) => {
                let dref = self
                    .engine
                    .registry_mut()
                    .register(name, Box::new(FileKind::create(path, len)?));
                if let MemInit::Data(v) = init {
                    self.engine.registry_mut().write(dref, None, 0, &v)?;
                }
                Ok(dref)
            }
        }
    }

    /// Front an arbitrary kind with a shared-window segment cache (the
    /// general form of `MemSpec::cached` — e.g. a [`FileKind`] archive
    /// too large for board memory).
    pub fn alloc_cached_kind(
        &mut self,
        name: &str,
        inner: Box<dyn MemKind>,
        spec: CacheSpec,
    ) -> Result<DataRef> {
        if spec.budget_bytes() > self.tech.shared_window {
            return Err(Error::Memory(format!(
                "cache budget {} B exceeds the {} B shared window",
                spec.budget_bytes(),
                self.tech.shared_window
            )));
        }
        let kind = SharedCacheKind::new(inner, spec)?;
        Ok(self.engine.registry_mut().register(name, Box::new(kind)))
    }

    /// Hit/miss accounting for one variable (`None` unless cache-fronted).
    pub fn cache_counters(&self, dref: DataRef) -> Result<Option<crate::sim::CacheCounters>> {
        self.engine.registry().cache_counters(dref)
    }

    /// Aggregate cache accounting over every live variable.
    pub fn total_cache_counters(&self) -> crate::sim::CacheCounters {
        self.engine.cache_counters()
    }

    /// Release a variable; later accesses through its references error.
    /// (The shard planner uses this to drop gather staging after a run.)
    pub fn release(&mut self, dref: DataRef) -> Result<()> {
        self.engine.registry_mut().release(dref)
    }

    /// Read a variable's (view's) contents from the host side.
    pub fn read(&self, dref: DataRef) -> Result<Vec<f32>> {
        self.engine.registry().read_all(dref, None)
    }

    /// Write into a variable from the host side.
    pub fn write(&mut self, dref: DataRef, off: usize, data: &[f32]) -> Result<()> {
        self.engine.registry_mut().write(dref, None, off, data)
    }

    // ---- device-resident data API (§2.2) ----------------------------------

    /// `define_on_device`: allocate a per-core device variable.
    pub fn define_on_device(&mut self, name: &str, len: usize) -> Result<DataRef> {
        self.alloc(MemSpec::microcore(name).zeroed(len))
    }

    /// `copy_to_device`: host → every core's replica.
    pub fn copy_to_device(&mut self, dref: DataRef, data: &[f32]) -> Result<()> {
        self.engine.registry_mut().write(dref, None, 0, data)
    }

    /// `copy_from_device`: one core's replica → host.
    pub fn copy_from_device(&self, dref: DataRef, core: usize) -> Result<Vec<f32>> {
        self.engine.registry().read_all(dref, Some(core))
    }

    // ---- kernels ----------------------------------------------------------

    /// Compile and register a kernel (entry = last `def`). Registration
    /// enforces this device's code/scratch budgets
    /// ([`crate::analysis::check_kernel_budget`]): a kernel whose bytecode
    /// cannot fit the technology's local store is rejected here with a
    /// typed [`Error::Analysis`] — these model hard device limits, so
    /// they apply regardless of the session's [`VerifyLevel`].
    pub fn compile_kernel(&mut self, name: &str, src: &str) -> Result<Kernel> {
        let k = self.kernels.register(name, src, None)?;
        self.enforce_budget(&k)?;
        Ok(k)
    }

    /// Compile with an explicit entry function (same budget enforcement
    /// as [`Session::compile_kernel`]).
    pub fn compile_kernel_entry(&mut self, name: &str, src: &str, entry: &str) -> Result<Kernel> {
        let k = self.kernels.register(name, src, Some(entry))?;
        self.enforce_budget(&k)?;
        Ok(k)
    }

    /// Reject a registered kernel that breaks this device's budgets.
    fn enforce_budget(&self, k: &Kernel) -> Result<()> {
        if let Some(d) = check_kernel_budget(k.name(), &k.program, &self.tech).into_iter().next()
        {
            return Err(Error::Analysis { launch: None, diagnostic: d.to_string() });
        }
        Ok(())
    }

    /// Look up a registered kernel.
    pub fn kernel(&self, name: &str) -> Result<&Kernel> {
        self.kernels.get(name)
    }

    // ---- asynchronous launches ------------------------------------------

    /// Begin building an asynchronous launch of `kernel`. Configure with
    /// [`LaunchBuilder::arg`]/[`args`](LaunchBuilder::args),
    /// [`cores`](LaunchBuilder::cores), [`mode`](LaunchBuilder::mode),
    /// [`prefetch`](LaunchBuilder::prefetch),
    /// [`after`](LaunchBuilder::after); then
    /// [`submit`](LaunchBuilder::submit) for an [`OffloadHandle`]. The
    /// builder's argument list doubles as the launch's read/write set —
    /// the engine infers dependency edges from it (module docs).
    pub fn launch(&mut self, kernel: &Kernel) -> LaunchBuilder<'_> {
        let options = OffloadOptions::default().tier(self.default_tier);
        LaunchBuilder { kernel: kernel.clone(), session: self, args: Vec::new(), options }
    }

    /// As [`Session::launch`], resolving the kernel by registry name. No
    /// deep copy — kernels are `Rc`-backed, so the resolved handle is two
    /// reference-count bumps.
    pub fn launch_named(&mut self, name: &str) -> Result<LaunchBuilder<'_>> {
        let kernel = self.kernels.get(name)?.clone();
        let options = OffloadOptions::default().tier(self.default_tier);
        Ok(LaunchBuilder { kernel, session: self, args: Vec::new(), options })
    }

    /// Drive the timeline until `handle`'s launch completes; claim its
    /// result (equivalently [`OffloadHandle::wait`]).
    pub fn wait(&mut self, handle: OffloadHandle) -> Result<OffloadResult> {
        self.engine.wait(handle.id)
    }

    /// Drive the timeline until every submitted launch completes. Results
    /// stay parked for each handle's [`OffloadHandle::wait`], which then
    /// returns immediately.
    pub fn wait_all(&mut self) -> Result<()> {
        self.engine.wait_all()
    }

    /// Drive the timeline until some launch is complete and unclaimed;
    /// returns its handle (`None` when nothing is in flight — if
    /// [`Session::in_flight`] is nonetheless positive, every remaining
    /// launch already has its outcome parked; claim them with their
    /// handles' `wait`).
    pub fn poll(&mut self) -> Result<Option<OffloadHandle>> {
        Ok(self.engine.poll()?.map(|id| OffloadHandle { id }))
    }

    /// Launches submitted but not yet complete (blocked + pending +
    /// active); see [`Session::queue_stats`] for the breakdown.
    pub fn in_flight(&self) -> usize {
        self.engine.in_flight()
    }

    /// Physical cores currently reserved or occupied by in-flight
    /// launches — the occupancy signal the multi-device group's automatic
    /// placement reads.
    pub fn busy_cores(&self) -> usize {
        self.engine.busy_cores()
    }

    /// Per-stage breakdown of the launch table: blocked on dependency
    /// edges vs queued on core contention vs active vs
    /// completed-unclaimed — so a caller can tell *why* nothing is
    /// running.
    pub fn queue_stats(&self) -> crate::coordinator::QueueStats {
        self.engine.queue_stats()
    }

    /// Drive the timeline until no in-flight launch can touch `dref`
    /// (host-side code about to read or write the variable directly uses
    /// this to order itself after device work; the shard planner drains
    /// the base variable this way before gather staging).
    pub fn quiesce(&mut self, dref: DataRef) -> Result<()> {
        self.engine.quiesce(dref)
    }

    // ---- static verification (see `crate::analysis`) ---------------------

    /// Whole-graph pre-flight over every launch still in the table:
    /// re-derives the scheduler's dependency edges from the analyzer's
    /// inferred flows and diffs them against the declared-flow edge set
    /// (plus the per-launch flow lints). Call it after submitting and
    /// *before* waiting — claimed launches leave the table. Pure
    /// analysis: no virtual time advances, works at any [`VerifyLevel`].
    pub fn verify_graph(&mut self) -> GraphReport {
        self.engine.verify_graph()
    }

    /// Drain the diagnostics collected by submit-time verification
    /// (empty unless the session was built with
    /// `SessionBuilder::verify(Warn|Strict)`).
    pub fn take_diagnostics(&mut self) -> Vec<Diagnostic> {
        self.engine.take_diagnostics()
    }
}

/// Builder for one asynchronous kernel launch (from [`Session::launch`]).
///
/// Holds the session borrow only until [`LaunchBuilder::submit`], which
/// returns a detached, copyable [`OffloadHandle`] — so any number of
/// launches can be in flight while the session stays usable.
#[derive(Debug)]
pub struct LaunchBuilder<'s> {
    session: &'s mut Session,
    kernel: Kernel,
    args: Vec<ArgSpec>,
    options: OffloadOptions,
}

impl LaunchBuilder<'_> {
    /// Append one argument.
    pub fn arg(mut self, arg: ArgSpec) -> Self {
        self.args.push(arg);
        self
    }

    /// Append a slice of arguments.
    pub fn args(mut self, args: &[ArgSpec]) -> Self {
        self.args.extend_from_slice(args);
        self
    }

    /// Restrict to a core subset (default: all device cores). Validated
    /// against the device at submit time ([`Technology::validate_cores`]).
    pub fn cores(mut self, cores: Vec<usize>) -> Self {
        self.options.cores = Some(cores);
        self
    }

    /// Set the argument transfer mode.
    pub fn mode(mut self, mode: TransferMode) -> Self {
        self.options.mode = mode;
        self
    }

    /// Set the default pre-fetch annotation (switches the mode to
    /// [`TransferMode::Prefetch`]).
    pub fn prefetch(mut self, spec: PrefetchSpec) -> Self {
        self.options = self.options.prefetch(spec);
        self
    }

    /// Set the per-core dispatch budget (runaway guard).
    pub fn fuel(mut self, fuel: u64) -> Self {
        self.options.fuel = fuel;
        self
    }

    /// Add an explicit dependency edge: this launch will not activate
    /// before `dep`'s launch completes, even if its cores are free and
    /// its data flow is disjoint. Edges may only point at
    /// already-submitted launches (forward/self edges are rejected at
    /// submit as cycles); an edge on a launch that failed parks
    /// [`crate::error::Error::DependencyFailed`] as this launch's
    /// outcome.
    pub fn after(self, dep: OffloadHandle) -> Self {
        self.after_id(dep.id())
    }

    /// As [`LaunchBuilder::after`], from a raw [`LaunchId`].
    pub fn after_id(mut self, dep: LaunchId) -> Self {
        self.options.after.push(dep);
        self
    }

    /// Opt out of inferred data-flow edges for this launch: it orders
    /// only behind its explicit `.after` edges and core contention.
    /// Unordered, not invisible — later launches still infer edges
    /// against its read/write set and [`Session::quiesce`] still drains
    /// it. *Mutable* data shared with earlier in-flight launches then
    /// gets §3.3's weak cross-launch memory model — deterministic
    /// interleaving, no ordering promise.
    pub fn independent(mut self) -> Self {
        self.options.flow_deps = false;
        self
    }

    /// Set the transient-fault retry budget: a faulted launch restores
    /// its last checkpoint and requeues on the same device, up to `n`
    /// times. Default 0 keeps today's fail-fast behavior — the first
    /// fault parks the error and poisons dependents.
    pub fn retry(mut self, n: u32) -> Self {
        self.options.retry = n;
        self
    }

    /// Virtual-time back-off inserted before each retry requeue (on top
    /// of the modeled checkpoint-restore read).
    pub fn backoff(mut self, t: Time) -> Self {
        self.options.backoff = t;
        self
    }

    /// Tag the launch with its owning tenant
    /// ([`OffloadOptions::tenant`] — fleet bookkeeping only, never
    /// scheduling).
    pub fn tenant(mut self, tenant: u64) -> Self {
        self.options.tenant = Some(tenant);
        self
    }

    /// Select this launch's execution tier ([`TierChoice`]): the
    /// bytecode interpreter (default), the compiled linear-IR tier, or
    /// `Auto` (the engine promotes repeated/hot kernels). Results,
    /// dispatch counts and suspension points are bit-identical across
    /// tiers; only host-side dispatch overhead and the pushed code-image
    /// bytes differ.
    pub fn tier(mut self, tier: TierChoice) -> Self {
        self.options.tier = tier;
        self
    }

    /// Replace the whole options block (migration aid for call sites that
    /// already hold an [`OffloadOptions`]); combine with the individual
    /// setters — including `.after`/`.independent` — by calling this
    /// first (it overwrites previously accumulated edges).
    pub fn options(mut self, options: OffloadOptions) -> Self {
        self.options = options;
        self
    }

    /// Validate the core selection, marshal the arguments and enqueue the
    /// launch. Returns without blocking and without advancing virtual
    /// time; the launch activates as soon as its cores are free and
    /// completes under [`OffloadHandle::wait`] / [`Session::wait_all`] /
    /// [`Session::poll`].
    pub fn submit(self) -> Result<OffloadHandle> {
        let LaunchBuilder { session, kernel, args, options } = self;
        let core_ids: Vec<usize> = match &options.cores {
            Some(ids) => {
                session.tech.validate_cores(ids)?;
                ids.clone()
            }
            None => (0..session.tech.cores).collect(),
        };
        let bound = bind(&args, &core_ids, options.mode, options.default_prefetch)?;
        let id = session.engine.submit(&kernel, bound, &options, &core_ids)?;
        Ok(OffloadHandle { id })
    }
}

/// A claim ticket for a submitted launch: plain `Copy` data, detached
/// from the session borrow. Redeem with [`OffloadHandle::wait`] (or
/// [`Session::wait`]); inspect with [`OffloadHandle::status`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OffloadHandle {
    id: LaunchId,
}

impl OffloadHandle {
    /// The engine-level launch id.
    pub fn id(&self) -> LaunchId {
        self.id
    }

    /// Drive the timeline until this launch completes; claim its result.
    /// Other in-flight launches progress as a side effect. Waiting twice
    /// is an error (the result is claimed by the first wait).
    pub fn wait(self, session: &mut Session) -> Result<OffloadResult> {
        session.engine.wait(self.id)
    }

    /// Lifecycle stage: blocked (waiting on dependency edges), pending
    /// (edges satisfied, queued on busy cores), active, or
    /// completed-unclaimed. `None` once waited.
    pub fn status(&self, session: &Session) -> Option<LaunchStatus> {
        session.engine.launch_status(self.id)
    }
}

/// Helper: unwrap a per-core return value as a numeric vector.
pub fn value_as_vec(v: &Value) -> Result<Vec<f64>> {
    Ok(v.as_array()?.borrow().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::marshal::PrefetchChoice;
    use crate::coordinator::{Access, PrefetchSpec, TransferMode};

    fn microcore_prefetch_default() -> PrefetchChoice {
        PrefetchChoice::Default
    }

    const SUM_SRC: &str = r#"
def mykernel(a, b):
    ret_data = [0.0] * len(a)
    i = 0
    while i < len(a):
        ret_data[i] = a[i] + b[i]
        i += 1
    return ret_data
"#;

    fn session() -> Session {
        Session::builder(Technology::epiphany3()).seed(7).build().unwrap()
    }

    fn pf(buf: usize, elems: usize) -> PrefetchSpec {
        PrefetchSpec {
            buffer_size: buf,
            elems_per_fetch: elems,
            distance: elems,
            access: Access::ReadOnly,
        }
    }

    #[test]
    fn listing1_on_demand_all_cores() {
        let mut s = session();
        let n = 160;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = vec![1000.0; n as usize];
        let ra = s.alloc(MemSpec::host("a").from(&a)).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&b)).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let h = s
            .launch(&k)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)])
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap();
        let res = h.wait(&mut s).unwrap();
        assert_eq!(res.reports.len(), 16);
        // Core 0 got elements [0, 10): expect a[i] + 1000
        let v0 = value_as_vec(&res.reports[0].value).unwrap();
        assert_eq!(v0.len(), 10);
        assert_eq!(v0[0], 1000.0);
        assert_eq!(v0[9], 1009.0);
        // Core 15 got [150, 160)
        let v15 = value_as_vec(&res.reports[15].value).unwrap();
        assert_eq!(v15[0], 1150.0);
        assert!(res.elapsed() > 0);
        assert!(res.total_requests() >= 2 * n as u64, "per-element traffic");
    }

    #[test]
    fn prefetch_beats_on_demand_on_elapsed_time() {
        let run = |mode_prefetch: bool| {
            let mut s = session();
            let n = 3200usize;
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![1.0f32; n];
            let ra = s.alloc(MemSpec::host("a").from(&a)).unwrap();
            let rb = s.alloc(MemSpec::host("b").from(&b)).unwrap();
            let k = s.compile_kernel("sum", SUM_SRC).unwrap();
            let builder =
                s.launch(&k).args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)]);
            let h = if mode_prefetch {
                builder.prefetch(pf(40, 20)).submit().unwrap()
            } else {
                builder.mode(TransferMode::OnDemand).submit().unwrap()
            };
            let res = h.wait(&mut s).unwrap();
            // correctness identical across modes (§3.1)
            let v = value_as_vec(&res.reports[0].value).unwrap();
            assert_eq!(v[5], (5 + 1) as f64);
            res.elapsed()
        };
        let od = run(false);
        let pfx = run(true);
        assert!(
            pfx * 3 < od,
            "prefetch ({pfx} ns) must be ≫ faster than on-demand ({od} ns)"
        );
    }

    #[test]
    fn eager_small_args_work_and_are_fast() {
        let mut s = session();
        let n = 320usize; // 20 elems/core: fits on-core
        let a = vec![2.0f32; n];
        let b = vec![3.0f32; n];
        let ra = s.alloc(MemSpec::host("a").from(&a)).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&b)).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let h = s
            .launch(&k)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)])
            .mode(TransferMode::Eager)
            .submit()
            .unwrap();
        let res = h.wait(&mut s).unwrap();
        assert_eq!(res.spills, 0);
        let v = value_as_vec(&res.reports[3].value).unwrap();
        assert!(v.iter().all(|&x| x == 5.0));
        // No channel requests for argument data (only result copy-back).
        for r in &res.reports {
            assert_eq!(r.counters.ext_reads, 0, "eager args are local");
        }
    }

    #[test]
    fn eager_oversized_args_spill_to_reference() {
        let mut s = session();
        // 4000 f32 per core = 16 KB > ~7 KB free: must spill.
        let n = 4000 * 16;
        let ra = s.alloc(MemSpec::host("a").zeroed(n)).unwrap();
        let rb = s.alloc(MemSpec::host("b").zeroed(n)).unwrap();
        let k = s.compile_kernel("first", "def first(a, b):\n    return a[0] + b[0]\n").unwrap();
        let h = s
            .launch(&k)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)])
            .mode(TransferMode::Eager)
            .submit()
            .unwrap();
        let res = h.wait(&mut s).unwrap();
        assert!(res.spills > 0, "paper's Listing-1 overflow scenario");
        // Spilled args still work (by reference): a[0] + b[0] = 0.0.
        assert_eq!(res.reports[0].value.as_f64().unwrap(), 0.0);
    }

    #[test]
    fn core_subset_runs_only_there() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[1.0; 40])).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&[2.0; 40])).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let h = s
            .launch(&k)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)])
            .mode(TransferMode::OnDemand)
            .cores(vec![2, 5])
            .submit()
            .unwrap();
        let res = h.wait(&mut s).unwrap();
        assert_eq!(res.reports.len(), 2);
        assert_eq!(res.reports[0].core, 2);
        assert_eq!(res.reports[1].core, 5);
        // Shards split across 2 cores: 20 each.
        assert_eq!(value_as_vec(&res.reports[0].value).unwrap().len(), 20);
    }

    #[test]
    fn out_of_range_core_rejected() {
        let mut s = session();
        let k = s.compile_kernel("k", "def k():\n    return 0\n").unwrap();
        let err = s.launch(&k).cores(vec![99]).submit();
        assert!(err.is_err());
        let msg = s.launch(&k).cores(vec![99]).submit().unwrap_err().to_string();
        assert!(msg.contains("out of range"), "{msg}");
    }

    #[test]
    fn duplicate_core_rejected() {
        let mut s = session();
        let k = s.compile_kernel("k", "def k():\n    return 0\n").unwrap();
        let msg = s.launch(&k).cores(vec![1, 1]).submit().unwrap_err().to_string();
        assert!(msg.contains("more than once"), "{msg}");
    }

    #[test]
    fn mutable_reference_writes_propagate_to_host() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[0.0; 32])).unwrap();
        let src = r#"
def scale(a):
    i = 0
    while i < len(a):
        a[i] = core_id() + 1.0
        i += 1
    return 0
"#;
        let k = s.compile_kernel("scale", src).unwrap();
        let h = s
            .launch(&k)
            .arg(ArgSpec::sharded_mut(ra))
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap();
        h.wait(&mut s).unwrap();
        let data = s.read(ra).unwrap();
        // Core i wrote (i+1) into its 2-element shard.
        assert_eq!(data[0], 1.0);
        assert_eq!(data[1], 1.0);
        assert_eq!(data[30], 16.0);
        assert_eq!(data[31], 16.0);
    }

    #[test]
    fn write_to_readonly_reference_is_typed_error() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[0.0; 16])).unwrap();
        let k = s
            .compile_kernel("w", "def w(a):\n    a[0] = 1.0\n    return 0\n")
            .unwrap();
        let h = s
            .launch(&k)
            .arg(ArgSpec::sharded(ra))
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap();
        let err = h.wait(&mut s).unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err}");
    }

    #[test]
    fn shared_kind_respects_window() {
        let mut s = session();
        // 10M f32 = 40 MB > 32 MB window
        assert!(s.alloc(MemSpec::shared("big").zeroed(10_000_000)).is_err());
        assert!(s.alloc(MemSpec::shared("ok").zeroed(1_000_000)).is_ok());
    }

    #[test]
    fn microcore_kind_per_core_replicas() {
        let mut s = session();
        let d = s.define_on_device("state", 16).unwrap();
        s.copy_to_device(d, &[7.0; 16]).unwrap();
        let src = r#"
def bump(state):
    state[0] = state[0] + core_id()
    return state[0]
"#;
        let k = s.compile_kernel("bump", src).unwrap();
        let h = s
            .launch(&k)
            .arg(ArgSpec::Ref {
                dref: d,
                shard: false,
                access: Access::Mutable,
                prefetch: microcore_prefetch_default(),
            })
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap();
        let res = h.wait(&mut s).unwrap();
        // Each core saw its own replica: 7 + core_id.
        assert_eq!(res.reports[0].value.as_f64().unwrap(), 7.0);
        assert_eq!(res.reports[5].value.as_f64().unwrap(), 12.0);
        assert_eq!(s.copy_from_device(d, 5).unwrap()[0], 12.0);
    }

    #[test]
    fn microcore_kind_too_large_rejected() {
        let mut s = session();
        assert!(
            s.alloc(MemSpec::microcore("big").zeroed(10_000)).is_err(),
            "40 KB > 32 KB store"
        );
    }

    #[test]
    fn microcore_init_broadcasts_to_replicas() {
        let mut s = session();
        let d = s.alloc(MemSpec::microcore("d").from(&[3.5; 8])).unwrap();
        assert_eq!(s.copy_from_device(d, 0).unwrap(), vec![3.5; 8]);
        assert_eq!(s.copy_from_device(d, 15).unwrap(), vec![3.5; 8]);
    }

    #[test]
    fn procedural_and_sink_specs_require_zeroed() {
        let mut s = session();
        assert!(s.alloc(MemSpec::procedural("w", 1, 0.01).zeroed(64)).is_ok());
        assert!(s.alloc(MemSpec::procedural("w2", 1, 0.01).from(&[1.0])).is_err());
        assert!(s.alloc(MemSpec::sink("g").zeroed(64)).is_ok());
        assert!(s.alloc(MemSpec::sink("g2").from(&[1.0])).is_err());
    }

    #[test]
    fn deterministic_same_seed_same_times() {
        let run = || {
            let mut s = Session::builder(Technology::epiphany3()).seed(99).build().unwrap();
            let ra = s.alloc(MemSpec::host("a").from(&[1.0; 320])).unwrap();
            let rb = s.alloc(MemSpec::host("b").from(&[2.0; 320])).unwrap();
            let k = s.compile_kernel("sum", SUM_SRC).unwrap();
            let h = s
                .launch(&k)
                .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)])
                .mode(TransferMode::OnDemand)
                .submit()
                .unwrap();
            h.wait(&mut s).unwrap().elapsed()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn virtual_time_is_monotonic_across_offloads() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[1.0; 32])).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&[2.0; 32])).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let t0 = s.now();
        let args = [ArgSpec::sharded(ra), ArgSpec::sharded(rb)];
        let h = s.launch(&k).args(&args).mode(TransferMode::OnDemand).submit().unwrap();
        h.wait(&mut s).unwrap();
        let t1 = s.now();
        let h = s.launch(&k).args(&args).mode(TransferMode::OnDemand).submit().unwrap();
        h.wait(&mut s).unwrap();
        let t2 = s.now();
        assert!(t0 < t1 && t1 < t2);
    }

    /// 0.4 removed the pre-0.3 shims; the unified surface carries every
    /// former spelling (this pins the grid's behaviour post-removal).
    #[test]
    fn unified_surface_covers_the_removed_grid() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[1.0; 32])).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&[2.0; 32])).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let res = s
            .launch(&k)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(rb)])
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap()
            .wait(&mut s)
            .unwrap();
        assert_eq!(value_as_vec(&res.reports[0].value).unwrap(), vec![3.0, 3.0]);
        assert!(s.alloc(MemSpec::shared("sz").zeroed(16)).is_ok());
        assert!(s.alloc(MemSpec::microcore("mc").zeroed(8)).is_ok());
        assert!(s.alloc(MemSpec::sink("sk").zeroed(8)).is_ok());
        assert!(s.alloc(MemSpec::procedural("pr", 1, 0.5).zeroed(8)).is_ok());
        assert!(s.launch_named("sum").is_ok());
    }

    #[test]
    fn oversized_kernel_rejected_at_registration_with_typed_error() {
        let mut s = session();
        // ~3000 fused float-accumulate lines ≈ 48 KB of code > the 32 KB
        // Epiphany-III local store (the former ad-hoc test asserts, now a
        // typed registration error from the analyzer's budget check).
        let mut src = String::from("def k():\n    x = 0.0\n");
        for _ in 0..3000 {
            src.push_str("    x = x + 1.0\n");
        }
        src.push_str("    return x\n");
        let err = s.compile_kernel("big", &src).unwrap_err();
        assert!(matches!(err, Error::Analysis { launch: None, .. }), "{err:?}");
        assert!(err.to_string().contains("local store"), "{err}");
        // The same kernel registers fine on the 64 KB MicroBlaze.
        let mut mb = Session::builder(Technology::microblaze()).build().unwrap();
        assert!(mb.compile_kernel("big", &src).is_ok());
    }

    #[test]
    fn strict_verify_rejects_under_declared_write_at_submit() {
        let mut s = Session::builder(Technology::epiphany3())
            .seed(7)
            .verify(VerifyLevel::Strict)
            .build()
            .unwrap();
        let ra = s.alloc(MemSpec::host("a").from(&[0.0; 16])).unwrap();
        // Writes a[0] but binds the argument read-only: the exact race the
        // scheduler cannot see. Strict mode rejects it before any engine
        // state changes.
        let k = s.compile_kernel("w", "def w(a):\n    a[0] = 1.0\n    return 0\n").unwrap();
        let err = s
            .launch(&k)
            .arg(ArgSpec::sharded(ra))
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap_err();
        assert!(matches!(err, Error::Analysis { launch: Some(_), .. }), "{err:?}");
        assert!(err.to_string().contains("[0, 1)"), "offending window in message: {err}");
        assert_eq!(s.in_flight(), 0, "rejected before entering the launch table");
        // Properly declared, the same kernel submits fine under Strict.
        let h = s
            .launch(&k)
            .arg(ArgSpec::sharded_mut(ra))
            .mode(TransferMode::OnDemand)
            .submit()
            .unwrap();
        h.wait(&mut s).unwrap();
    }

    #[test]
    fn explicit_after_edge_blocks_until_dependency_finishes() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[1.0; 32])).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&[2.0; 32])).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        // Disjoint cores AND disjoint data: only the explicit edge orders
        // them.
        let h1 = s
            .launch(&k)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(ra)])
            .mode(TransferMode::OnDemand)
            .cores((0..4).collect())
            .submit()
            .unwrap();
        let h2 = s
            .launch(&k)
            .args(&[ArgSpec::sharded(rb), ArgSpec::sharded(rb)])
            .mode(TransferMode::OnDemand)
            .cores((4..8).collect())
            .after(h1)
            .submit()
            .unwrap();
        assert_eq!(h2.status(&s), Some(LaunchStatus::Blocked), "edge unsatisfied");
        let qs = s.queue_stats();
        assert_eq!((qs.blocked, qs.pending), (1, 1));
        let r1 = h1.wait(&mut s).unwrap();
        let r2 = h2.wait(&mut s).unwrap();
        assert_eq!(r2.launched_at, r1.finished_at, "activates at the dependency's finish");
    }

    #[test]
    fn inferred_flow_edge_orders_writer_after_reader() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[5.0; 32])).unwrap();
        let reader = s.compile_kernel("sum", SUM_SRC).unwrap();
        let writer = s
            .compile_kernel(
                "fill",
                "def fill(a):\n    i = 0\n    while i < len(a):\n        a[i] = 9.0\n        i += 1\n    return 0\n",
            )
            .unwrap();
        let hr = s
            .launch(&reader)
            .args(&[ArgSpec::sharded(ra), ArgSpec::sharded(ra)])
            .mode(TransferMode::OnDemand)
            .cores((0..4).collect())
            .submit()
            .unwrap();
        let hw = s
            .launch(&writer)
            .arg(ArgSpec::sharded_mut(ra))
            .mode(TransferMode::OnDemand)
            .cores((4..8).collect())
            .submit()
            .unwrap();
        assert_eq!(hw.status(&s), Some(LaunchStatus::Blocked), "WAR edge inferred");
        let rr = hr.wait(&mut s).unwrap();
        // The reader saw pre-write contents: write-after-read ordering.
        // (32 elements over 4 cores = 8 per shard; 5.0 + 5.0 each.)
        assert_eq!(value_as_vec(&rr.reports[0].value).unwrap(), vec![10.0; 8]);
        let rw = hw.wait(&mut s).unwrap();
        assert_eq!(rw.launched_at, rr.finished_at);
        assert_eq!(s.read(ra).unwrap(), vec![9.0; 32]);
    }

    #[test]
    fn handle_status_and_wait_all() {
        let mut s = session();
        let ra = s.alloc(MemSpec::host("a").from(&[1.0; 32])).unwrap();
        let rb = s.alloc(MemSpec::host("b").from(&[2.0; 32])).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let args = [ArgSpec::sharded(ra), ArgSpec::sharded(rb)];
        let h1 = s
            .launch(&k)
            .args(&args)
            .mode(TransferMode::OnDemand)
            .cores((0..8).collect())
            .submit()
            .unwrap();
        let h2 = s
            .launch(&k)
            .args(&args)
            .mode(TransferMode::OnDemand)
            .cores((0..8).collect())
            .submit()
            .unwrap();
        // Nothing runs until a wait/poll drives the timeline.
        assert_eq!(h1.status(&s), Some(LaunchStatus::Pending));
        assert_eq!(h2.status(&s), Some(LaunchStatus::Pending));
        assert_eq!(s.in_flight(), 2);
        let first = s.poll().unwrap().expect("a launch completes");
        assert_eq!(first, h1, "submission order completes first under core contention");
        assert_eq!(h1.status(&s), Some(LaunchStatus::Completed));
        s.wait_all().unwrap();
        assert_eq!(h2.status(&s), Some(LaunchStatus::Completed));
        let r1 = h1.wait(&mut s).unwrap();
        let r2 = h2.wait(&mut s).unwrap();
        assert_eq!(r2.launched_at, r1.finished_at, "contended launch queues behind");
        assert_eq!(s.in_flight(), 0);
        assert!(s.wait(h1).is_err(), "double wait is an error");
    }
}

