//! The user-facing session: the ePython module surface, in Rust.
//!
//! A [`Session`] owns one simulated device plus the host-side runtime:
//! memory kinds, kernel registry, offload engine, and (optionally) the
//! PJRT executor for tensor builtins. Its API mirrors the paper's Python
//! surface:
//!
//! | paper (Python)                         | here                                      |
//! |----------------------------------------|-------------------------------------------|
//! | `memkind.Host(types.int, 1000)`        | [`Session::alloc_host_f32`]               |
//! | `memkind.Shared(...)`                  | [`Session::alloc_shared_f32`]             |
//! | `memkind.Microcore(...)`               | [`Session::alloc_microcore_f32`]          |
//! | `@offload` + call                      | [`Session::compile_kernel`] + [`Session::offload`] |
//! | `prefetch={...}` decorator argument    | [`ArgSpec::with_prefetch`] / [`OffloadOptions::prefetch`] |
//! | `define_on_device` / `copy_to_device` / `copy_from_device` | [`Session::define_on_device`] / [`Session::copy_to_device`] / [`Session::copy_from_device`] |
//!
//! Changing where data lives is one call-site change — swap the alloc
//! method — with everything downstream (reference decoding, transfer
//! costs, host staging) following from the kind, as §3.2 prescribes.

use crate::device::Technology;
use crate::error::{Error, Result};
use crate::memory::{
    CacheSpec, DataRef, FileKind, HostKind, MemKind, MicrocoreKind, ProceduralKind,
    SharedCacheKind, SharedKind, SinkKind,
};
use crate::runtime::{ModelExecutor, PjrtContext};
use crate::sim::Time;
use crate::vm::Value;

use super::engine::{Engine, EngineStats};
use super::marshal::{bind, ArgSpec};
use super::offload::{Kernel, KernelRegistry, OffloadOptions, OffloadResult};

/// Builder for [`Session`].
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    tech: Technology,
    artifacts_dir: Option<String>,
    service_threads: usize,
    seed: u64,
    trace_capacity: Option<usize>,
}

impl SessionBuilder {
    /// Start building a session for a technology preset.
    pub fn new(tech: Technology) -> Self {
        SessionBuilder {
            tech,
            artifacts_dir: None,
            service_threads: 1,
            seed: 42,
            trace_capacity: None,
        }
    }

    /// Attach AOT artifacts (enables PJRT-backed tensor builtins).
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Host service threads (§4 models one dedicated thread by default).
    pub fn service_threads(mut self, n: usize) -> Self {
        self.service_threads = n.max(1);
        self
    }

    /// Deterministic seed for service jitter and synthetic content.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Record a bounded event trace.
    pub fn trace(mut self, capacity: usize) -> Self {
        self.trace_capacity = Some(capacity);
        self
    }

    /// Construct the session.
    pub fn build(self) -> Result<Session> {
        let exec = match &self.artifacts_dir {
            Some(dir) => Some(ModelExecutor::new(PjrtContext::new(dir)?)),
            None => None,
        };
        let mut engine = Engine::new(self.tech.clone(), self.service_threads, self.seed, exec);
        if let Some(cap) = self.trace_capacity {
            engine.enable_trace(cap);
        }
        Ok(Session { tech: self.tech, engine, kernels: KernelRegistry::new() })
    }
}

/// A live offload session against one simulated micro-core device.
#[derive(Debug)]
pub struct Session {
    tech: Technology,
    engine: Engine,
    kernels: KernelRegistry,
}

impl Session {
    /// Builder entry point.
    pub fn builder(tech: Technology) -> SessionBuilder {
        SessionBuilder::new(tech)
    }

    /// The technology preset.
    pub fn tech(&self) -> &Technology {
        &self.tech
    }

    /// The engine (stats, trace, service knobs).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutable engine access.
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Engine statistics snapshot.
    pub fn stats(&self) -> EngineStats {
        self.engine.stats()
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.engine.now()
    }

    // ---- memory kinds (§3.2) --------------------------------------------

    /// Allocate in host memory (top of the hierarchy; on the Epiphany the
    /// cores cannot address this — every access is host-serviced).
    pub fn alloc_host_f32(&mut self, name: &str, data: &[f32]) -> Result<DataRef> {
        Ok(self
            .engine
            .registry_mut()
            .register(name, Box::new(HostKind::from_vec(data.to_vec()))))
    }

    /// Allocate zeroed host memory.
    pub fn alloc_host_zeroed(&mut self, name: &str, len: usize) -> Result<DataRef> {
        Ok(self.engine.registry_mut().register(name, Box::new(HostKind::zeroed(len))))
    }

    /// Allocate in the shared window (device-addressable; bounded by the
    /// technology's window size — the Epiphany's 32 MB).
    pub fn alloc_shared_f32(&mut self, name: &str, data: &[f32]) -> Result<DataRef> {
        let kind = SharedKind::from_vec(data.to_vec(), self.tech.shared_window)?;
        Ok(self.engine.registry_mut().register(name, Box::new(kind)))
    }

    /// Allocate zeroed shared-window memory.
    pub fn alloc_shared_zeroed(&mut self, name: &str, len: usize) -> Result<DataRef> {
        let kind = SharedKind::zeroed(len, self.tech.shared_window)?;
        Ok(self.engine.registry_mut().register(name, Box::new(kind)))
    }

    /// Allocate one replica per core in local store (`Microcore` kind;
    /// §3.2's device-resident data). Checked against the per-core budget.
    pub fn alloc_microcore_f32(&mut self, name: &str, len: usize) -> Result<DataRef> {
        let bytes = len * 4;
        if bytes > self.tech.user_store() {
            return Err(Error::ScratchpadExhausted {
                core: 0,
                requested: bytes,
                free: self.tech.user_store(),
            });
        }
        Ok(self
            .engine
            .registry_mut()
            .register(name, Box::new(MicrocoreKind::zeroed(self.tech.cores, len))))
    }

    /// Allocate a *procedural* (generated-on-read) variable in the shared
    /// level — used where the paper's dense full-size tensors cannot
    /// physically exist in board memory (DESIGN.md substitution table).
    pub fn alloc_procedural_f32(
        &mut self,
        name: &str,
        seed: u64,
        len: usize,
        scale: f32,
    ) -> Result<DataRef> {
        Ok(self
            .engine
            .registry_mut()
            .register(name, Box::new(ProceduralKind::new(seed, len, scale))))
    }

    /// Allocate a write-only sink variable (gradient stream destination in
    /// the full-size regime).
    pub fn alloc_sink_f32(&mut self, name: &str, len: usize) -> Result<DataRef> {
        Ok(self.engine.registry_mut().register(name, Box::new(SinkKind::new(len))))
    }

    /// Allocate host memory fronted by a shared-window segment cache
    /// ([`SharedCacheKind`]): the first device pass streams across the
    /// off-chip boundary; repeated passes are serviced at shared-window
    /// cost. The cache budget must fit the technology's window.
    pub fn alloc_host_cached_f32(
        &mut self,
        name: &str,
        data: &[f32],
        spec: CacheSpec,
    ) -> Result<DataRef> {
        self.alloc_cached_kind(name, Box::new(HostKind::from_vec(data.to_vec())), spec)
    }

    /// Front an arbitrary kind with a shared-window segment cache (the
    /// general form of [`Session::alloc_host_cached_f32`] — e.g. a
    /// [`FileKind`] archive too large for board memory).
    pub fn alloc_cached_kind(
        &mut self,
        name: &str,
        inner: Box<dyn MemKind>,
        spec: CacheSpec,
    ) -> Result<DataRef> {
        if spec.budget_bytes() > self.tech.shared_window {
            return Err(Error::Memory(format!(
                "cache budget {} B exceeds the {} B shared window",
                spec.budget_bytes(),
                self.tech.shared_window
            )));
        }
        let kind = SharedCacheKind::new(inner, spec)?;
        Ok(self.engine.registry_mut().register(name, Box::new(kind)))
    }

    /// Hit/miss accounting for one variable (`None` unless cache-fronted).
    pub fn cache_counters(&self, dref: DataRef) -> Result<Option<crate::sim::CacheCounters>> {
        self.engine.registry().cache_counters(dref)
    }

    /// Aggregate cache accounting over every live variable.
    pub fn total_cache_counters(&self) -> crate::sim::CacheCounters {
        self.engine.cache_counters()
    }

    /// Release a variable; later accesses through its references error.
    /// (The shard planner uses this to drop gather staging after a run.)
    pub fn release(&mut self, dref: DataRef) -> Result<()> {
        self.engine.registry_mut().release(dref)
    }

    /// Allocate a file-backed variable (the extensibility kind of §4).
    pub fn alloc_file_f32(
        &mut self,
        name: &str,
        path: impl Into<std::path::PathBuf>,
        len: usize,
    ) -> Result<DataRef> {
        Ok(self.engine.registry_mut().register(name, Box::new(FileKind::create(path, len)?)))
    }

    /// Read a variable's (view's) contents from the host side.
    pub fn read(&self, dref: DataRef) -> Result<Vec<f32>> {
        self.engine.registry().read_all(dref, None)
    }

    /// Write into a variable from the host side.
    pub fn write(&mut self, dref: DataRef, off: usize, data: &[f32]) -> Result<()> {
        self.engine.registry_mut().write(dref, None, off, data)
    }

    // ---- device-resident data API (§2.2) ----------------------------------

    /// `define_on_device`: allocate a per-core device variable.
    pub fn define_on_device(&mut self, name: &str, len: usize) -> Result<DataRef> {
        self.alloc_microcore_f32(name, len)
    }

    /// `copy_to_device`: host → every core's replica.
    pub fn copy_to_device(&mut self, dref: DataRef, data: &[f32]) -> Result<()> {
        self.engine.registry_mut().write(dref, None, 0, data)
    }

    /// `copy_from_device`: one core's replica → host.
    pub fn copy_from_device(&self, dref: DataRef, core: usize) -> Result<Vec<f32>> {
        self.engine.registry().read_all(dref, Some(core))
    }

    // ---- kernels ----------------------------------------------------------

    /// Compile and register a kernel (entry = last `def`).
    pub fn compile_kernel(&mut self, name: &str, src: &str) -> Result<Kernel> {
        self.kernels.register(name, src, None)
    }

    /// Compile with an explicit entry function.
    pub fn compile_kernel_entry(&mut self, name: &str, src: &str, entry: &str) -> Result<Kernel> {
        self.kernels.register(name, src, Some(entry))
    }

    /// Look up a registered kernel.
    pub fn kernel(&self, name: &str) -> Result<&Kernel> {
        self.kernels.get(name)
    }

    /// Offload a kernel (blocking, collective across the selected cores).
    pub fn offload(
        &mut self,
        kernel: &Kernel,
        args: &[ArgSpec],
        options: OffloadOptions,
    ) -> Result<OffloadResult> {
        let core_ids: Vec<usize> = match &options.cores {
            Some(ids) => {
                for &id in ids {
                    if id >= self.tech.cores {
                        return Err(Error::Coordinator(format!(
                            "core {id} out of range (device has {})",
                            self.tech.cores
                        )));
                    }
                }
                ids.clone()
            }
            None => (0..self.tech.cores).collect(),
        };
        let bound = bind(args, &core_ids, options.mode, options.default_prefetch)?;
        self.engine.offload(kernel, bound, &options, &core_ids)
    }

    /// Convenience: offload by kernel name.
    pub fn offload_named(
        &mut self,
        kernel: &str,
        args: &[ArgSpec],
        options: OffloadOptions,
    ) -> Result<OffloadResult> {
        let k = self.kernels.get(kernel)?.clone();
        self.offload(&k, args, options)
    }
}

/// Helper: unwrap a per-core return value as a numeric vector.
pub fn value_as_vec(v: &Value) -> Result<Vec<f64>> {
    Ok(v.as_array()?.borrow().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::marshal::PrefetchChoice;
    use crate::coordinator::{Access, PrefetchSpec, TransferMode};

    fn microcore_prefetch_default() -> PrefetchChoice {
        PrefetchChoice::Default
    }

    const SUM_SRC: &str = r#"
def mykernel(a, b):
    ret_data = [0.0] * len(a)
    i = 0
    while i < len(a):
        ret_data[i] = a[i] + b[i]
        i += 1
    return ret_data
"#;

    fn session() -> Session {
        Session::builder(Technology::epiphany3()).seed(7).build().unwrap()
    }

    fn pf(buf: usize, elems: usize) -> PrefetchSpec {
        PrefetchSpec {
            buffer_size: buf,
            elems_per_fetch: elems,
            distance: elems,
            access: Access::ReadOnly,
        }
    }

    #[test]
    fn listing1_on_demand_all_cores() {
        let mut s = session();
        let n = 160;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = vec![1000.0; n as usize];
        let ra = s.alloc_host_f32("a", &a).unwrap();
        let rb = s.alloc_host_f32("b", &b).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let res = s
            .offload(
                &k,
                &[ArgSpec::sharded(ra), ArgSpec::sharded(rb)],
                OffloadOptions::default().transfer(TransferMode::OnDemand),
            )
            .unwrap();
        assert_eq!(res.reports.len(), 16);
        // Core 0 got elements [0, 10): expect a[i] + 1000
        let v0 = value_as_vec(&res.reports[0].value).unwrap();
        assert_eq!(v0.len(), 10);
        assert_eq!(v0[0], 1000.0);
        assert_eq!(v0[9], 1009.0);
        // Core 15 got [150, 160)
        let v15 = value_as_vec(&res.reports[15].value).unwrap();
        assert_eq!(v15[0], 1150.0);
        assert!(res.elapsed() > 0);
        assert!(res.total_requests() >= 2 * n as u64, "per-element traffic");
    }

    #[test]
    fn prefetch_beats_on_demand_on_elapsed_time() {
        let run = |mode_prefetch: bool| {
            let mut s = session();
            let n = 3200usize;
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![1.0f32; n];
            let ra = s.alloc_host_f32("a", &a).unwrap();
            let rb = s.alloc_host_f32("b", &b).unwrap();
            let k = s.compile_kernel("sum", SUM_SRC).unwrap();
            let opts = if mode_prefetch {
                OffloadOptions::default().prefetch(pf(40, 20))
            } else {
                OffloadOptions::default().transfer(TransferMode::OnDemand)
            };
            let res = s
                .offload(&k, &[ArgSpec::sharded(ra), ArgSpec::sharded(rb)], opts)
                .unwrap();
            // correctness identical across modes (§3.1)
            let v = value_as_vec(&res.reports[0].value).unwrap();
            assert_eq!(v[5], (5 + 1) as f64);
            res.elapsed()
        };
        let od = run(false);
        let pfx = run(true);
        assert!(
            pfx * 3 < od,
            "prefetch ({pfx} ns) must be ≫ faster than on-demand ({od} ns)"
        );
    }

    #[test]
    fn eager_small_args_work_and_are_fast() {
        let mut s = session();
        let n = 320usize; // 20 elems/core: fits on-core
        let a = vec![2.0f32; n];
        let b = vec![3.0f32; n];
        let ra = s.alloc_host_f32("a", &a).unwrap();
        let rb = s.alloc_host_f32("b", &b).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let res = s
            .offload(
                &k,
                &[ArgSpec::sharded(ra), ArgSpec::sharded(rb)],
                OffloadOptions::default().transfer(TransferMode::Eager),
            )
            .unwrap();
        assert_eq!(res.spills, 0);
        let v = value_as_vec(&res.reports[3].value).unwrap();
        assert!(v.iter().all(|&x| x == 5.0));
        // No channel requests for argument data (only result copy-back).
        for r in &res.reports {
            assert_eq!(r.counters.ext_reads, 0, "eager args are local");
        }
    }

    #[test]
    fn eager_oversized_args_spill_to_reference() {
        let mut s = session();
        // 4000 f32 per core = 16 KB > ~7 KB free: must spill.
        let n = 4000 * 16;
        let ra = s.alloc_host_zeroed("a", n).unwrap();
        let rb = s.alloc_host_zeroed("b", n).unwrap();
        let k = s.compile_kernel("first", "def first(a, b):\n    return a[0] + b[0]\n").unwrap();
        let res = s
            .offload(
                &k,
                &[ArgSpec::sharded(ra), ArgSpec::sharded(rb)],
                OffloadOptions::default().transfer(TransferMode::Eager),
            )
            .unwrap();
        assert!(res.spills > 0, "paper's Listing-1 overflow scenario");
        // Spilled args still work (by reference): a[0] + b[0] = 0.0.
        assert_eq!(res.reports[0].value.as_f64().unwrap(), 0.0);
    }

    #[test]
    fn core_subset_runs_only_there() {
        let mut s = session();
        let ra = s.alloc_host_f32("a", &[1.0; 40]).unwrap();
        let rb = s.alloc_host_f32("b", &[2.0; 40]).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let res = s
            .offload(
                &k,
                &[ArgSpec::sharded(ra), ArgSpec::sharded(rb)],
                OffloadOptions::default()
                    .transfer(TransferMode::OnDemand)
                    .on_cores(vec![2, 5]),
            )
            .unwrap();
        assert_eq!(res.reports.len(), 2);
        assert_eq!(res.reports[0].core, 2);
        assert_eq!(res.reports[1].core, 5);
        // Shards split across 2 cores: 20 each.
        assert_eq!(value_as_vec(&res.reports[0].value).unwrap().len(), 20);
    }

    #[test]
    fn out_of_range_core_rejected() {
        let mut s = session();
        let k = s.compile_kernel("k", "def k():\n    return 0\n").unwrap();
        let err = s.offload(&k, &[], OffloadOptions::default().on_cores(vec![99]));
        assert!(err.is_err());
    }

    #[test]
    fn mutable_reference_writes_propagate_to_host() {
        let mut s = session();
        let ra = s.alloc_host_f32("a", &[0.0; 32]).unwrap();
        let src = r#"
def scale(a):
    i = 0
    while i < len(a):
        a[i] = core_id() + 1.0
        i += 1
    return 0
"#;
        let k = s.compile_kernel("scale", src).unwrap();
        s.offload(
            &k,
            &[ArgSpec::sharded_mut(ra)],
            OffloadOptions::default().transfer(TransferMode::OnDemand),
        )
        .unwrap();
        let data = s.read(ra).unwrap();
        // Core i wrote (i+1) into its 2-element shard.
        assert_eq!(data[0], 1.0);
        assert_eq!(data[1], 1.0);
        assert_eq!(data[30], 16.0);
        assert_eq!(data[31], 16.0);
    }

    #[test]
    fn write_to_readonly_reference_is_typed_error() {
        let mut s = session();
        let ra = s.alloc_host_f32("a", &[0.0; 16]).unwrap();
        let k = s
            .compile_kernel("w", "def w(a):\n    a[0] = 1.0\n    return 0\n")
            .unwrap();
        let err = s
            .offload(
                &k,
                &[ArgSpec::sharded(ra)],
                OffloadOptions::default().transfer(TransferMode::OnDemand),
            )
            .unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err}");
    }

    #[test]
    fn shared_kind_respects_window() {
        let mut s = session();
        // 10M f32 = 40 MB > 32 MB window
        assert!(s.alloc_shared_zeroed("big", 10_000_000).is_err());
        assert!(s.alloc_shared_zeroed("ok", 1_000_000).is_ok());
    }

    #[test]
    fn microcore_kind_per_core_replicas() {
        let mut s = session();
        let d = s.define_on_device("state", 16).unwrap();
        s.copy_to_device(d, &[7.0; 16]).unwrap();
        let src = r#"
def bump(state):
    state[0] = state[0] + core_id()
    return state[0]
"#;
        let k = s.compile_kernel("bump", src).unwrap();
        let res = s
            .offload(
                &k,
                &[ArgSpec::Ref {
                    dref: d,
                    shard: false,
                    access: Access::Mutable,
                    prefetch: microcore_prefetch_default(),
                }],
                OffloadOptions::default().transfer(TransferMode::OnDemand),
            )
            .unwrap();
        // Each core saw its own replica: 7 + core_id.
        assert_eq!(res.reports[0].value.as_f64().unwrap(), 7.0);
        assert_eq!(res.reports[5].value.as_f64().unwrap(), 12.0);
        assert_eq!(s.copy_from_device(d, 5).unwrap()[0], 12.0);
    }

    #[test]
    fn microcore_kind_too_large_rejected() {
        let mut s = session();
        assert!(s.alloc_microcore_f32("big", 10_000).is_err(), "40 KB > 32 KB store");
    }

    #[test]
    fn deterministic_same_seed_same_times() {
        let run = || {
            let mut s = Session::builder(Technology::epiphany3()).seed(99).build().unwrap();
            let ra = s.alloc_host_f32("a", &[1.0; 320]).unwrap();
            let rb = s.alloc_host_f32("b", &[2.0; 320]).unwrap();
            let k = s.compile_kernel("sum", SUM_SRC).unwrap();
            s.offload(
                &k,
                &[ArgSpec::sharded(ra), ArgSpec::sharded(rb)],
                OffloadOptions::default().transfer(TransferMode::OnDemand),
            )
            .unwrap()
            .elapsed()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn virtual_time_is_monotonic_across_offloads() {
        let mut s = session();
        let ra = s.alloc_host_f32("a", &[1.0; 32]).unwrap();
        let rb = s.alloc_host_f32("b", &[2.0; 32]).unwrap();
        let k = s.compile_kernel("sum", SUM_SRC).unwrap();
        let t0 = s.now();
        let args = [ArgSpec::sharded(ra), ArgSpec::sharded(rb)];
        s.offload(&k, &args, OffloadOptions::default().transfer(TransferMode::OnDemand))
            .unwrap();
        let t1 = s.now();
        s.offload(&k, &args, OffloadOptions::default().transfer(TransferMode::OnDemand))
            .unwrap();
        let t2 = s.now();
        assert!(t0 < t1 && t1 < t2);
    }
}
