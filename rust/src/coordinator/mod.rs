//! The host-side offload coordinator — the paper's contribution.
//!
//! §3 defines the programming model this module implements:
//!
//! * **Kernel offload** ([`offload`], [`session`]) — kernels are compiled
//!   once and invoked across all (or a subset of) micro-cores through the
//!   asynchronous launch surface: `session.launch(&k)` builds the
//!   invocation, `.submit()` returns an [`OffloadHandle`], and
//!   `wait`/`wait_all`/`poll` drive completion. Launches form a
//!   *launch graph*: dependency edges are inferred from each launch's
//!   argument read/write sets (plus explicit `.after` edges), so a
//!   dependent chain submitted without intervening waits executes
//!   bit-identically to the blocking sequence while independent launches
//!   pipeline on the shared virtual timeline ([`engine`]'s launch graph).
//! * **Pass by reference** ([`marshal`]) — instead of eagerly copying
//!   argument data to the device, the coordinator sends opaque
//!   [`crate::memory::DataRef`]s; element accesses on the cores become
//!   channel requests serviced by the host ([`service`]).
//! * **Pre-fetching** ([`prefetch`]) — the
//!   `prefetch={var, buffer, elems_per_fetch, distance, access}`
//!   annotation turns blocking per-element round-trips into streamed,
//!   overlapped chunk transfers into a reserved on-core buffer.
//! * **The engine** ([`engine`]) — a deterministic min-clock discrete-event
//!   scheduler that interleaves the per-core VMs, the channel protocol,
//!   the host service threads, the shared link, and PJRT tensor-builtin
//!   execution, producing both *numerics* (real data moves, the model
//!   really trains) and *virtual-time* measurements (the paper's figures).
//! * **Sharding** ([`shard`]) — the multi-core offload planner: an
//!   explicit partition of a variable over N cores (block or block-cyclic
//!   with gather/scatter staging and write-back merge), the ownership
//!   model every later scaling layer builds on. [`ShardPlan::across_devices`]
//!   splits a shard set over a device group proportionally to core counts.
//! * **Multi-device plans** ([`group`]) — a [`DeviceGroup`] owns one
//!   engine per attached technology on a shared virtual timeline;
//!   launches place explicitly (`.on(device)`) or automatically by
//!   per-device occupancy, and cross-device data flow becomes inferred
//!   edges plus host-level staging copies (no device ever reads another
//!   device's local window directly), so the launch graph — edges,
//!   failure propagation, quiesce — spans heterogeneous devices.

pub mod engine;
pub mod group;
pub mod marshal;
pub mod offload;
pub mod prefetch;
pub mod service;
pub mod session;
pub mod shard;

pub use engine::{Engine, EngineStats, LaunchCheckpoint, LaunchId, LaunchStatus, OffloadOutcome, QueueStats, TierCounters};
pub use group::{DeviceGroup, DeviceId, GroupArgSpec, GroupHandle, GroupLaunchBuilder, GroupRef, GroupSession};
pub use marshal::{ArgSpec, BoundArg, PrefetchChoice};
pub use offload::{Kernel, KernelRegistry, OffloadOptions, OffloadResult};
pub use prefetch::{PrefetchSpec, PrefetchState};
pub use service::HostService;
pub use session::{value_as_vec, LaunchBuilder, OffloadHandle, Session, SessionBuilder};
pub use shard::{ShardAssignment, ShardPlan, ShardPolicy};

// The static verifier's user-facing types, re-exported where the session
// builder that consumes them lives (the analysis itself is
// [`crate::analysis`]).
pub use crate::analysis::{GraphReport, VerifyLevel};

// The execution-tier selector, re-exported where the launch options that
// carry it live (the tiers themselves are [`crate::vm::tier`]).
pub use crate::vm::TierChoice;

/// How kernel arguments travel to the device (§3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferMode {
    /// Legacy ePython behaviour: copy the entire argument to the core at
    /// launch (fails or spills for data larger than the local store).
    Eager,
    /// Pass by reference; every element access is a blocking round-trip.
    OnDemand,
    /// Pass by reference with the pre-fetch engine filling a reserved
    /// on-core buffer ahead of use.
    Prefetch,
}

impl TransferMode {
    /// Parse from the config-file spelling.
    pub fn parse(s: &str) -> Option<TransferMode> {
        match s {
            "eager" => Some(TransferMode::Eager),
            "on-demand" | "ondemand" => Some(TransferMode::OnDemand),
            "prefetch" | "pre-fetch" => Some(TransferMode::Prefetch),
            _ => None,
        }
    }

    /// Config-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            TransferMode::Eager => "eager",
            TransferMode::OnDemand => "on-demand",
            TransferMode::Prefetch => "prefetch",
        }
    }
}

/// Read/write intent of a reference argument — the paper's *access
/// modifier* ("whether the data is mutable ... or read only (so no copy
/// back is required)").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Access {
    /// Read-only: no write-back traffic is ever generated.
    #[default]
    ReadOnly,
    /// Mutable: element writes are written through to the owning level
    /// (atomic per element; ordered within a core, unordered across cores
    /// — §3.3's weak memory model).
    Mutable,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [TransferMode::Eager, TransferMode::OnDemand, TransferMode::Prefetch] {
            assert_eq!(TransferMode::parse(m.name()), Some(m));
        }
        assert_eq!(TransferMode::parse("bogus"), None);
    }
}
