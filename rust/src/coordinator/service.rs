//! The host-side request service: §4's "dedicated thread on the host CPU".
//!
//! ## Cost model (calibrated to the paper's Table 2)
//!
//! Two very different data paths exist on these boards, and the paper's
//! numbers only make sense with both modelled:
//!
//! * **Cell protocol** ([`HostService::service`]) — the host thread picks a
//!   request out of a shared-memory cell, decodes the reference, and
//!   copies the payload through *uncached* shared memory word by word.
//!   On the Parallella this path runs at roughly 1.3 MB/s (the well-known
//!   slow CPU view of Epiphany shared memory), which is exactly what
//!   Table 2 measures: ~0.10 ms for 128 B, ~0.82 ms for 1 KB, ~7.9 ms for
//!   8 KB — linear in size with a small per-request handshake. The
//!   min/max spread comes from host-thread scheduling jitter ("with other
//!   activities on the same CPU this response time can vary").
//! * **Bulk DMA** ([`HostService::dma`]) — device-initiated transfers from
//!   device-addressable levels use the DMA engine at the *achieved link
//!   bandwidth* (88 MB/s Epiphany, ~100 MB/s MicroBlaze).
//! * **Legacy marshalled path** ([`HostService::eager_push`]) — the
//!   pre-paper eager argument copy was relayed through the separate
//!   ePython host process (§5.1: the new mechanism "communicate[s]
//!   directly with the ePython VM ... rather than having to go via the
//!   ePython host process"), costing an IPC hop plus a double copy.
//!   This is why pre-fetch can beat eager despite moving the same bytes.
//!
//! Requests are submitted in global virtual-time order by the engine's
//! min-clock scheduler, keeping all resources causally consistent.

use crate::device::Technology;
use crate::memory::{Hierarchy, Level};
use crate::sim::{Resource, Rng, Time, Timeline, USEC};

/// Per-byte cost of the uncached shared-memory protocol copy (ns/byte) at
/// the Epiphany's nominal 88 MB/s link. 760 ns/B ≈ 1.3 MB/s — Table 2's
/// slope. The uncached CPU accesses ride the *same* physical link as DMA,
/// so the effective protocol rate scales with the achieved link bandwidth
/// (this is what makes pre-fetching increasingly important as the link
/// degrades — §6).
const PROTOCOL_NS_PER_BYTE_NOMINAL: f64 = 760.0;

/// Link bandwidth the nominal protocol rate was calibrated at.
const NOMINAL_LINK_BW: f64 = 88_000_000.0;

/// Fixed handshake per serviced request (cell scan + reference decode).
const HANDSHAKE: Time = 18 * USEC;

/// Mean of the exponential host-thread scheduling jitter.
const JITTER_MEAN: Time = 8 * USEC;

/// IPC hop through the legacy ePython host process (eager path).
const LEGACY_IPC: Time = 350 * USEC;

/// Modelled host service: threads + link.
#[derive(Debug)]
pub struct HostService {
    threads: Resource,
    link: Timeline,
    hierarchy: Hierarchy,
    rng: Rng,
    serviced: u64,
    protocol_ns_per_byte: u64,
}

impl HostService {
    /// Build for a technology with `threads` service threads and an RNG
    /// stream for pickup jitter.
    pub fn new(tech: &Technology, threads: usize, rng: Rng) -> Self {
        HostService {
            threads: Resource::new(threads.max(1)),
            link: Timeline::new(tech.link_bw_achieved, tech.link_latency),
            hierarchy: Hierarchy::new(tech),
            rng,
            serviced: 0,
            protocol_ns_per_byte: Self::protocol_rate(tech.link_bw_achieved),
        }
    }

    /// Protocol copy cost tracks the achieved link rate (uncached CPU
    /// accesses share the physical link with DMA), clamped so a faster
    /// link never beats the calibrated nominal.
    fn protocol_rate(link_bw: u64) -> u64 {
        let scaled = PROTOCOL_NS_PER_BYTE_NOMINAL * (NOMINAL_LINK_BW / link_bw as f64);
        scaled.max(PROTOCOL_NS_PER_BYTE_NOMINAL * 0.8) as u64
    }

    /// Hierarchy facts (addressability checks for DMA).
    pub fn hierarchy(&self) -> &Hierarchy {
        &self.hierarchy
    }

    /// Service one channel request of `bytes` wire size targeting data at
    /// `level`, submitted at `now`. Returns the virtual time the response
    /// lands in the core's cell. Cell-protocol path: handshake + jitter +
    /// staging + uncached copy, then the link hop.
    pub fn service(&mut self, now: Time, level: Level, bytes: u64) -> Time {
        let jitter = self.rng.exponential(JITTER_MEAN as f64) as Time;
        let staging = self.hierarchy.staging_cost(level, bytes);
        let copy = bytes * self.protocol_ns_per_byte;
        let work = HANDSHAKE + jitter + staging + copy;
        let (_, picked) = self.threads.allocate(now, work);
        let (_, done) = self.link.allocate(picked, bytes);
        self.serviced += 1;
        done
    }

    /// A direct DMA transfer (no host thread, no cells): the device reads
    /// or writes `bytes` at a device-addressable `level` at full link
    /// bandwidth. Panics in debug if the level is not addressable
    /// (callers must route that traffic through [`HostService::service`]).
    pub fn dma(&mut self, now: Time, level: Level, bytes: u64) -> Time {
        debug_assert!(
            self.hierarchy.addressable(level),
            "DMA to non-addressable level {level:?}"
        );
        let (_, done) = self.link.allocate(now, bytes);
        done
    }

    /// Legacy eager argument copy (marshalled via the ePython host
    /// process): IPC hop + double protocol copy + link.
    pub fn eager_push(&mut self, now: Time, level: Level, bytes: u64) -> Time {
        let staging = self.hierarchy.staging_cost(level, bytes);
        let work = LEGACY_IPC + staging + 2 * bytes * self.protocol_ns_per_byte;
        let (_, picked) = self.threads.allocate(now, work);
        let (_, done) = self.link.allocate(picked, bytes);
        self.serviced += 1;
        done
    }

    /// Kernel byte-code push at launch (the new direct path, single copy).
    pub fn push_code(&mut self, now: Time, bytes: u64) -> Time {
        let work = HANDSHAKE + bytes * self.protocol_ns_per_byte;
        let (_, picked) = self.threads.allocate(now, work);
        let (_, done) = self.link.allocate(picked, bytes);
        done
    }

    /// Requests serviced so far.
    pub fn serviced(&self) -> u64 {
        self.serviced
    }

    /// Total bytes that crossed the link.
    pub fn link_bytes(&self) -> u64 {
        self.link.bytes_moved()
    }

    /// Total link transfers.
    pub fn link_transfers(&self) -> u64 {
        self.link.transfers()
    }

    /// Link utilization over `[0, horizon]`.
    pub fn link_utilization(&self, horizon: Time) -> f64 {
        self.link.utilization(horizon)
    }

    /// Effective link bandwidth over `[0, horizon]` (bytes/s).
    pub fn effective_bandwidth(&self, horizon: Time) -> f64 {
        self.link.effective_bandwidth(horizon)
    }

    /// Degrade / restore the link rate (the Epiphany's observed 88 → 16
    /// MB/s band; bandwidth-sweep ablation).
    pub fn set_link_bandwidth(&mut self, bytes_per_sec: u64) {
        self.link.set_bandwidth(bytes_per_sec);
        self.protocol_ns_per_byte = Self::protocol_rate(bytes_per_sec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Technology;
    use crate::sim::{MSEC, SEC};

    fn svc() -> HostService {
        HostService::new(&Technology::epiphany3(), 1, Rng::new(7))
    }

    /// Mean isolated stall for a request of `payload` bytes (+32 B header).
    fn mean_stall_ms(payload: u64) -> f64 {
        let mut s = svc();
        let mut total = 0.0;
        let n = 200;
        for i in 0..n {
            let t0 = (i as u64) * 50 * MSEC; // spaced out: no queueing
            let done = s.service(t0, Level::Shared, payload + 32);
            total += (done - t0) as f64;
        }
        total / n as f64 / MSEC as f64
    }

    #[test]
    fn table2_128b_row_calibration() {
        let m = mean_stall_ms(128);
        // Paper: 0.104 ms mean
        assert!((0.08..0.20).contains(&m), "mean {m} ms");
    }

    #[test]
    fn table2_1kb_row_calibration() {
        let m = mean_stall_ms(1024);
        // Paper: 0.816 ms mean
        assert!((0.6..1.1).contains(&m), "mean {m} ms");
    }

    #[test]
    fn table2_8kb_row_calibration() {
        let m = mean_stall_ms(8192);
        // Paper: 7.882 ms mean
        assert!((5.5..9.5).contains(&m), "mean {m} ms");
    }

    #[test]
    fn host_level_pays_staging() {
        let mut s = svc();
        let shared = s.service(0, Level::Shared, 8 * 1024);
        let mut s2 = svc();
        let host = s2.service(0, Level::Host, 8 * 1024);
        assert!(host > shared, "staging adds time: {host} vs {shared}");
    }

    #[test]
    fn contention_serializes_on_one_thread() {
        let mut s = svc();
        let a = s.service(0, Level::Shared, 1024);
        let b = s.service(0, Level::Shared, 1024);
        assert!(b > a, "second request queues behind the first");
        assert_eq!(s.serviced(), 2);
    }

    #[test]
    fn more_threads_reduce_queueing() {
        let one = {
            let mut s = HostService::new(&Technology::epiphany3(), 1, Rng::new(3));
            (0..8).map(|_| s.service(0, Level::Shared, 64)).max().unwrap()
        };
        let four = {
            let mut s = HostService::new(&Technology::epiphany3(), 4, Rng::new(3));
            (0..8).map(|_| s.service(0, Level::Shared, 64)).max().unwrap()
        };
        assert!(four < one, "4 threads {four} < 1 thread {one}");
    }

    #[test]
    fn dma_runs_at_link_bandwidth() {
        let mut s = svc();
        // 88 MB at 88 MB/s ≈ 1 s (+ 2 us latency)
        let done = s.dma(0, Level::Shared, 88_000_000);
        assert!((done as f64 - SEC as f64).abs() < 0.01 * SEC as f64, "{done}");
    }

    #[test]
    fn protocol_path_much_slower_than_dma() {
        let mut s = svc();
        let dma = s.dma(0, Level::Shared, 14_400);
        let mut s2 = svc();
        let proto = s2.service(0, Level::Shared, 14_400);
        assert!(proto > 20 * dma, "protocol {proto} vs dma {dma}");
    }

    #[test]
    fn eager_legacy_path_costs_more_than_direct() {
        let mut s = svc();
        let direct = s.push_code(0, 1024);
        let mut s2 = svc();
        let legacy = s2.eager_push(0, Level::Shared, 1024);
        assert!(legacy > direct + LEGACY_IPC / 2, "{legacy} vs {direct}");
    }

    #[test]
    #[should_panic(expected = "non-addressable")]
    #[cfg(debug_assertions)]
    fn dma_to_host_level_on_epiphany_panics() {
        let mut s = svc();
        s.dma(0, Level::Host, 1024);
    }

    #[test]
    fn microblaze_dma_to_host_level_allowed() {
        let mut s = HostService::new(&Technology::microblaze_fpu(), 1, Rng::new(1));
        let done = s.dma(0, Level::Host, 1024);
        assert!(done > 0);
    }

    #[test]
    fn bandwidth_degradation_slows_dma() {
        let mut s = svc();
        let fast = s.dma(0, Level::Shared, 1_000_000);
        s.set_link_bandwidth(16_000_000);
        let t1 = fast + MSEC;
        let slow = s.dma(t1, Level::Shared, 1_000_000) - t1;
        assert!(slow > (fast as f64 * 4.0) as u64, "16 MB/s ≫ slower than 88 MB/s");
    }
}
