//! The sharded offload planner: one logical kernel over N cores.
//!
//! The paper distributes data by handing each core a contiguous window of
//! the argument (`DataRef::shards`, ePython's pixel distribution). That is
//! one point in a bigger design space: load balance and locality often
//! want **block-cyclic** decomposition (ePython's own successors and the
//! Vipera studies both shard this way), where fixed-size blocks are dealt
//! round-robin so hot regions spread across cores. A [`ShardPlan`] makes
//! the decomposition an explicit, inspectable object:
//!
//! * [`ShardPolicy::Block`] — contiguous near-equal windows, zero-copy:
//!   each core's shard is a [`DataRef`] sub-view of the base variable.
//! * [`ShardPolicy::BlockCyclic`] — blocks dealt round-robin. A core's
//!   shard is no longer contiguous, so [`ShardPlan::execute`] **gathers**
//!   each core's ranges into a per-core staging variable at launch
//!   (host-side, the registry is the single source of truth), offloads,
//!   and — for mutable shards — **scatters** the staging contents back
//!   into the base variable afterwards (write-back merge). Staging
//!   variables are released before `execute` returns.
//!
//! Ownership model: ranges of a plan are disjoint and cover the base view
//! exactly once, so every element has exactly one writer and the merge
//! order across cores is irrelevant — N-core runs produce bit-identical
//! results to the 1-core reference for element-wise kernels (enforced by
//! `tests/sharded_cache.rs`). Later scaling layers (async batching,
//! multi-device) extend this planner rather than re-deriving per-core
//! windows at call sites.
//!
//! In the launch graph a sharded offload participates as **one dependency
//! group**: [`ShardPlan::execute`] first quiesces the base variable
//! (draining any in-flight launch whose data flow touches it — the edge
//! its host-side gather staging needs), then submits a single launch
//! whose per-core windows form one flow set, so later submissions order
//! against the whole sharded run, not its fragments.
//!
//! The planner composes with the rest of the stack: shards work in any
//! [`super::TransferMode`] and pre-fetch annotations apply per shard. A
//! base variable fronted by a [`crate::memory::SharedCacheKind`] serves
//! repeated **block**-sharded passes out of the shared window (block
//! shards are zero-copy views of the base, so device traffic reaches the
//! cache). Block-*cyclic* shards stream their host-side staging copies
//! instead — correct, but cache-bypassing: pick `Block` when combining
//! sharding with a cached base.

use crate::error::{Error, Result};
use crate::memory::{DataRef, HostKind};

use super::marshal::{ArgSpec, PrefetchChoice};
use super::offload::{Kernel, OffloadOptions, OffloadResult};
use super::session::Session;
use super::Access;

/// How a variable is partitioned over the participating cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// One contiguous, near-equal window per core (earlier cores take the
    /// remainder — the classic ePython distribution). Zero-copy.
    Block,
    /// Fixed-size blocks dealt round-robin across cores. Balances skewed
    /// access cost at the price of gather/scatter staging.
    BlockCyclic {
        /// Elements per dealt block (must be positive).
        block_elems: usize,
    },
}

/// One core's share of a plan: view-relative `(offset, len)` ranges of the
/// base variable, in stream order. The core sees them concatenated as one
/// local view.
#[derive(Debug, Clone)]
pub struct ShardAssignment {
    /// Disjoint ranges owned by this core, ascending.
    pub ranges: Vec<(usize, usize)>,
}

impl ShardAssignment {
    /// Total elements this core owns.
    pub fn elems(&self) -> usize {
        self.ranges.iter().map(|r| r.1).sum()
    }

    /// Whether the shard is a single contiguous window (no staging
    /// needed).
    pub fn is_contiguous(&self) -> bool {
        self.ranges.len() <= 1
    }
}

/// A partition of one base [`DataRef`] over N cores (module docs).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    base: DataRef,
    policy: ShardPolicy,
    assignments: Vec<ShardAssignment>,
}

impl ShardPlan {
    /// Partition `base` over `cores` cores under `policy`.
    pub fn new(base: DataRef, cores: usize, policy: ShardPolicy) -> Result<ShardPlan> {
        if cores == 0 {
            return Err(Error::Coordinator("shard plan requires at least one core".into()));
        }
        let assignments = match policy {
            ShardPolicy::Block => {
                let per = base.len / cores;
                let rem = base.len % cores;
                let mut out = Vec::with_capacity(cores);
                let mut off = 0;
                for i in 0..cores {
                    let l = per + usize::from(i < rem);
                    out.push(ShardAssignment { ranges: vec![(off, l)] });
                    off += l;
                }
                out
            }
            ShardPolicy::BlockCyclic { block_elems } => {
                if block_elems == 0 {
                    return Err(Error::Coordinator(
                        "block-cyclic sharding requires a positive block size".into(),
                    ));
                }
                let mut out = vec![ShardAssignment { ranges: Vec::new() }; cores];
                let mut off = 0;
                let mut turn = 0usize;
                while off < base.len {
                    let l = block_elems.min(base.len - off);
                    out[turn % cores].ranges.push((off, l));
                    off += l;
                    turn += 1;
                }
                out
            }
        };
        Ok(ShardPlan { base, policy, assignments })
    }

    /// The base view this plan partitions.
    pub fn base(&self) -> DataRef {
        self.base
    }

    /// The decomposition policy.
    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    /// Per-core assignments (index = position among participating cores).
    pub fn assignments(&self) -> &[ShardAssignment] {
        &self.assignments
    }

    /// Number of participating cores.
    pub fn cores(&self) -> usize {
        self.assignments.len()
    }

    /// Split `base` over a *device group* proportionally to per-device
    /// core counts, then partition each device's slice over its cores
    /// under `policy` — the device-aware decomposition a
    /// [`crate::coordinator::GroupSession`] schedules one slice-plan per
    /// device from. Device `i` receives a contiguous slice of
    /// `⌊len·Σcounts[..=i]/Σcounts⌋ − ⌊len·Σcounts[..i]/Σcounts⌋`
    /// elements (floor-of-cumulative-share, so the slices are disjoint,
    /// cover `base` exactly once, and each is within one element of its
    /// exact proportional share). A 16-core Epiphany paired with an
    /// 8-core MicroBlaze therefore takes two thirds of the data.
    pub fn across_devices(
        base: DataRef,
        core_counts: &[usize],
        policy: ShardPolicy,
    ) -> Result<Vec<ShardPlan>> {
        Self::device_split(base, core_counts)?
            .into_iter()
            .zip(core_counts)
            .map(|(slice, &cores)| ShardPlan::new(slice, cores, policy))
            .collect()
    }

    /// The per-device contiguous slices behind
    /// [`ShardPlan::across_devices`] (exposed for drivers that stage the
    /// slices themselves).
    pub fn device_split(base: DataRef, core_counts: &[usize]) -> Result<Vec<DataRef>> {
        if core_counts.is_empty() {
            return Err(Error::Coordinator("device split requires at least one device".into()));
        }
        if core_counts.iter().any(|&c| c == 0) {
            return Err(Error::Coordinator(
                "device split requires every device to contribute at least one core".into(),
            ));
        }
        let total: usize = core_counts.iter().sum();
        let mut out = Vec::with_capacity(core_counts.len());
        let mut cum = 0usize;
        let mut prev_end = 0usize;
        for &c in core_counts {
            cum += c;
            let end = base.len * cum / total;
            out.push(base.slice(prev_end, end - prev_end));
            prev_end = end;
        }
        Ok(out)
    }

    /// Run `kernel` with this plan's shard as the **first** kernel
    /// argument (`extra` args follow it), on the cores named by
    /// `options.cores` (default: all device cores; the count must match
    /// the plan's).
    ///
    /// Contiguous shards bind as zero-copy sub-views. Non-contiguous
    /// shards are gathered into per-core staging variables before launch
    /// and — when `access` is [`Access::Mutable`] — scatter-merged back
    /// into the base variable after completion; staging is always
    /// released. Gather/scatter are host-side registry moves (free in
    /// virtual time): the *modelled* traffic is exactly what the cores
    /// pull through the channels, which is what the paper times.
    pub fn execute(
        &self,
        session: &mut Session,
        kernel: &Kernel,
        access: Access,
        prefetch: PrefetchChoice,
        extra: &[ArgSpec],
        options: OffloadOptions,
    ) -> Result<OffloadResult> {
        let core_ids: Vec<usize> = match &options.cores {
            Some(ids) => {
                // Same uniform validation as the session's launch path.
                session.tech().validate_cores(ids)?;
                ids.clone()
            }
            None => (0..session.tech().cores).collect(),
        };
        if core_ids.len() != self.assignments.len() {
            return Err(Error::Coordinator(format!(
                "shard plan partitions over {} cores but the offload runs on {}",
                self.assignments.len(),
                core_ids.len()
            )));
        }
        let base_name =
            session.engine().registry().name(self.base).unwrap_or("shard").to_string();

        // One dependency group: drain every in-flight launch whose data
        // flow can touch the base variable before doing anything
        // host-side. Contiguous shards bind base sub-views, so the
        // launch's own flow set covers the base and later submissions
        // order against it through the graph; gathered (block-cyclic)
        // shards additionally read the base *on the host* right here,
        // which the graph cannot defer — the quiesce supplies exactly the
        // read-after-write edge the staging copy needs. The launch itself
        // is waited below, so the scatter-merge write-back is ordered
        // too.
        session.quiesce(self.base)?;

        // Bind: zero-copy sub-views where contiguous, gather staging
        // otherwise.
        let mut drefs = Vec::with_capacity(core_ids.len());
        let mut staging: Vec<Option<DataRef>> = Vec::with_capacity(core_ids.len());
        for (ci, asg) in self.assignments.iter().enumerate() {
            if let [(off, len)] = asg.ranges[..] {
                drefs.push(self.base.slice(off, len));
                staging.push(None);
            } else {
                let mut buf: Vec<f32> = Vec::with_capacity(asg.elems());
                for &(off, len) in &asg.ranges {
                    buf.extend(session.read(self.base.slice(off, len))?);
                }
                let sref = session
                    .engine_mut()
                    .registry_mut()
                    .register(format!("{base_name}.c{ci}"), Box::new(HostKind::from_vec(buf)));
                drefs.push(sref);
                staging.push(Some(sref));
            }
        }

        let mut args = Vec::with_capacity(1 + extra.len());
        args.push(ArgSpec::PerCore { drefs, access, prefetch });
        args.extend_from_slice(extra);
        let opts = OffloadOptions { cores: Some(core_ids), ..options };
        let submitted = session.launch(kernel).args(&args).options(opts).submit();
        let result = submitted.and_then(|h| h.wait(session));

        // Write-back merge, then release staging. Every staging variable
        // is released even when the offload or an earlier merge step
        // failed — the first error is reported after cleanup.
        let mut merge_err: Option<Error> = None;
        for (asg, st) in self.assignments.iter().zip(&staging) {
            let Some(sref) = st else { continue };
            if result.is_ok() && access == Access::Mutable && merge_err.is_none() {
                let merged = (|| -> Result<()> {
                    let vals = session.read(*sref)?;
                    let mut pos = 0;
                    for &(off, len) in &asg.ranges {
                        session.write(self.base, off, &vals[pos..pos + len])?;
                        pos += len;
                    }
                    Ok(())
                })();
                if let Err(e) = merged {
                    merge_err = Some(e);
                }
            }
            session.release(*sref)?;
        }
        if let Some(e) = merge_err {
            return Err(e);
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TransferMode;
    use crate::device::Technology;
    use crate::memory::MemSpec;

    fn base(len: usize) -> DataRef {
        DataRef { id: 3, offset: 0, len }
    }

    /// Every element is owned exactly once, ranges ascend per core.
    fn assert_exact_cover(plan: &ShardPlan, len: usize) {
        let mut owned = vec![0u8; len];
        for asg in plan.assignments() {
            let mut prev_end = 0;
            for &(off, l) in &asg.ranges {
                assert!(off >= prev_end, "ranges ascend within a core");
                prev_end = off + l;
                for o in owned.iter_mut().skip(off).take(l) {
                    *o += 1;
                }
            }
        }
        assert!(owned.iter().all(|&c| c == 1), "exactly-once coverage");
    }

    #[test]
    fn block_plan_matches_shards_split() {
        let plan = ShardPlan::new(base(10), 4, ShardPolicy::Block).unwrap();
        let lens: Vec<usize> = plan.assignments().iter().map(|a| a.elems()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2], "remainder to earlier cores");
        assert!(plan.assignments().iter().all(|a| a.is_contiguous()));
        assert_exact_cover(&plan, 10);
    }

    #[test]
    fn block_cyclic_deals_round_robin() {
        let plan =
            ShardPlan::new(base(100), 3, ShardPolicy::BlockCyclic { block_elems: 10 }).unwrap();
        // blocks: 0,10,...,90 dealt to cores 0,1,2,0,1,2,...
        assert_eq!(plan.assignments()[0].ranges, vec![(0, 10), (30, 10), (60, 10), (90, 10)]);
        assert_eq!(plan.assignments()[1].ranges, vec![(10, 10), (40, 10), (70, 10)]);
        assert_eq!(plan.assignments()[2].elems(), 30);
        assert!(!plan.assignments()[0].is_contiguous());
        assert_exact_cover(&plan, 100);
    }

    #[test]
    fn block_cyclic_tail_block_is_partial() {
        let plan =
            ShardPlan::new(base(25), 2, ShardPolicy::BlockCyclic { block_elems: 10 }).unwrap();
        assert_eq!(plan.assignments()[0].ranges, vec![(0, 10), (20, 5)]);
        assert_eq!(plan.assignments()[1].ranges, vec![(10, 10)]);
        assert_exact_cover(&plan, 25);
    }

    #[test]
    fn degenerate_plans_validated() {
        assert!(ShardPlan::new(base(10), 0, ShardPolicy::Block).is_err());
        assert!(
            ShardPlan::new(base(10), 2, ShardPolicy::BlockCyclic { block_elems: 0 }).is_err()
        );
        // More cores than elements: trailing cores own nothing.
        let plan = ShardPlan::new(base(3), 5, ShardPolicy::Block).unwrap();
        assert_exact_cover(&plan, 3);
        assert_eq!(plan.assignments()[4].elems(), 0);
    }

    #[test]
    fn device_split_is_proportional_and_covers_exactly() {
        // 16-core Epiphany + 8-core MicroBlaze: 2:1 split.
        let slices = ShardPlan::device_split(base(3600), &[16, 8]).unwrap();
        assert_eq!(slices.len(), 2);
        assert_eq!(slices[0].len, 2400);
        assert_eq!(slices[1].len, 1200);
        assert_eq!(slices[0].offset, 0);
        assert_eq!(slices[1].offset, 2400, "contiguous, disjoint");
        // Rounding: slices stay within one element of the exact share.
        let slices = ShardPlan::device_split(base(100), &[3, 7]).unwrap();
        assert_eq!(slices[0].len + slices[1].len, 100, "exact cover");
        assert!((slices[0].len as f64 - 30.0).abs() <= 1.0);
        // Degenerate inputs rejected.
        assert!(ShardPlan::device_split(base(10), &[]).is_err());
        assert!(ShardPlan::device_split(base(10), &[4, 0]).is_err());
    }

    #[test]
    fn across_devices_builds_one_plan_per_device() {
        let plans =
            ShardPlan::across_devices(base(3600), &[16, 8], ShardPolicy::Block).unwrap();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].cores(), 16);
        assert_eq!(plans[1].cores(), 8);
        assert_exact_cover(&plans[0], 2400);
        // Device 1's plan partitions the *slice* (offsets are view-local).
        assert_eq!(plans[1].base().offset, 2400);
        assert_eq!(plans[1].assignments().iter().map(ShardAssignment::elems).sum::<usize>(), 1200);
        // Composes with block-cyclic too.
        let plans = ShardPlan::across_devices(
            base(300),
            &[2, 1],
            ShardPolicy::BlockCyclic { block_elems: 10 },
        )
        .unwrap();
        assert!(!plans[0].assignments()[0].is_contiguous());
    }

    #[test]
    fn execute_merges_mutable_cyclic_shards_back() {
        let mut s = Session::builder(Technology::epiphany3()).seed(11).build().unwrap();
        let data: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let d = s.alloc(MemSpec::host("xs").from(&data)).unwrap();
        let k = s
            .compile_kernel(
                "bump",
                "def bump(x):\n    i = 0\n    while i < len(x):\n        x[i] = x[i] + 1.0\n        i += 1\n    return 0\n",
            )
            .unwrap();
        let plan = ShardPlan::new(d, 4, ShardPolicy::BlockCyclic { block_elems: 5 }).unwrap();
        let vars_before = s.engine().registry().len();
        plan.execute(
            &mut s,
            &k,
            Access::Mutable,
            PrefetchChoice::Default,
            &[],
            OffloadOptions::default()
                .transfer(TransferMode::OnDemand)
                .on_cores(vec![0, 1, 2, 3]),
        )
        .unwrap();
        assert_eq!(s.engine().registry().len(), vars_before, "staging released");
        let out = s.read(d).unwrap();
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32 + 1.0, "element {i} merged back");
        }
    }

    #[test]
    fn execute_rejects_core_count_mismatch() {
        let mut s = Session::builder(Technology::epiphany3()).seed(1).build().unwrap();
        let d = s.alloc(MemSpec::host("xs").zeroed(16)).unwrap();
        let k = s.compile_kernel("k", "def k(x):\n    return 0\n").unwrap();
        let plan = ShardPlan::new(d, 4, ShardPolicy::Block).unwrap();
        let err = plan.execute(
            &mut s,
            &k,
            Access::ReadOnly,
            PrefetchChoice::Default,
            &[],
            OffloadOptions::default().on_cores(vec![0, 1]),
        );
        assert!(err.is_err());
    }
}
