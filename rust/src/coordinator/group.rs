//! Multi-device execution plans: one launch surface spanning
//! heterogeneous micro-core technologies.
//!
//! The paper evaluates the same abstractions on two very different
//! devices — Epiphany-III and MicroBlaze — but a [`super::Session`]
//! drives exactly one of them. A [`DeviceGroup`] builds a
//! [`GroupSession`] that owns **one engine per attached
//! [`Technology`]** on a shared virtual timeline, so one driver can
//! schedule work across an Epiphany *and* a MicroBlaze simultaneously,
//! with the host memory hierarchy as the shared staging level (ePython's
//! virtualised-core model and Vipera's portable runtime both target
//! heterogeneous devices behind one API; this layer brings that into the
//! launch graph).
//!
//! ## Placement
//!
//! [`GroupSession::launch_named`] returns a [`GroupLaunchBuilder`] — the
//! familiar launch builder plus [`GroupLaunchBuilder::on`], which pins
//! the launch to a device. Without `.on(..)` placement is **automatic**:
//! the launch goes to the device with the lowest core occupancy
//! (reserved/busy cores ÷ total cores, ties to the lower device index) —
//! deterministic, so runs replay bit-for-bit.
//!
//! ## The staging invariant
//!
//! **No device ever reads another device's local window directly;
//! everything crosses at Host level or above.** Group buffers
//! ([`GroupSession::alloc`]) therefore must live at the Host level (plain
//! or cache-fronted) and are *replicated*: each device's registry holds
//! its own copy. The group tracks, per buffer, which replica is
//! **authoritative** (the device whose launch last wrote it) and which
//! replicas are fresh. When a launch on device B touches a buffer whose
//! authoritative replica is on device A, submit performs a **host-level
//! staging copy** — the cross-device analogue of an inferred RAW edge:
//!
//! 1. device A is quiesced for the buffer (the writer finishes — exactly
//!    [`super::Session::quiesce`], so the edge spans devices);
//! 2. device B is quiesced for its replica (in-flight readers of the old
//!    contents finish before the overwrite — the WAR half);
//! 3. one **host-level read** is charged on A's service and one
//!    **host-level write** on B's service, the levels probed through
//!    [`crate::memory::MemRegistry::access_level`] (a cache-fronted
//!    source resident in its shared window is charged at `Shared` cost);
//! 4. the dependent launch is submitted with an activation floor
//!    ([`super::OffloadOptions::not_before`]) at the copy's completion —
//!    it activates no earlier than the staged data's arrival, exactly
//!    like an in-engine edge raising `dep_ready`.
//!
//! [`crate::sim::StagingCounters`] audits the 1 copy : 1 host read :
//! 1 host write relationship; a two-device chain charges exactly one
//! host-level read and one host-level write more than the same chain on
//! one device (`tests/multi_device.rs`).
//!
//! ## Failure propagation across devices
//!
//! A staging copy is a host-side read of the writer's output, so the
//! group refuses to stage from a failed writer: the dependent launch
//! parks its own [`Error::DependencyFailed`] naming the writer *and its
//! device* (`dep_device`), and — if it would itself have written buffers
//! — records itself as their failed writer (replica contents and
//! freshness stay exactly as they were: a parked launch never ran), so
//! the abandonment propagates transitively through cross-device
//! *staging* chains just as the engine's worklist propagates it within a
//! device. A successor that can read its replica **without staging**
//! proceeds on the data as it is — the same blocking-continue semantics
//! the engine applies to inferred edges onto already-failed launches. A
//! full-cover host write ([`GroupSession::write`]) clears the poison
//! along with the staleness.
//!
//! ## What stays per-device
//!
//! Device-private kinds (`Shared`, `Microcore`, …) are allocated through
//! the underlying [`GroupSession::session_mut`] and never cross devices
//! — that is the staging invariant again. Within one device all engine
//! semantics are unchanged: the per-device launch graph still infers
//! edges, pipelines disjoint launches and propagates failures exactly as
//! `coordinator/engine.rs` documents.
//!
//! Staleness is tracked per whole buffer (the hull), mirroring the
//! engine's per-variable [`FlowSpan`](super::engine) hulls: a window
//! write marks the entire buffer authoritative on the writer's device.
//! Conservative — a spurious staging copy costs time, never correctness.
//!
//! ## Fault migration
//!
//! Transient core faults are the engine's business: a retry-budgeted
//! launch restores its last checkpoint and requeues on the *same* device
//! ([`super::OffloadOptions::retry`]). The group steps in only for
//! **permanent device loss** ([`crate::sim::FaultPlan::lose_device`],
//! installed per device via [`DeviceGroup::faults`]): when a
//! retry-budgeted launch's device dies, its handle's `wait` harvests the
//! launch's last checkpoint from the dead engine
//! ([`super::Engine::harvest_checkpoint`]), stages it through **Host
//! level** — one host read charged on the lost device's service (loss
//! kills cores, not host windows) and one host write on the survivor's,
//! audited by [`crate::sim::StagingCounters`] like any staging copy —
//! re-freshens the launch's group-buffer inputs on the target, and
//! resumes it there with the remaining budget. Placement reuses the
//! occupancy heuristic over *surviving* devices with enough cores
//! (checkpoint entries are positional, so the core count is preserved).
//! No capable survivor exhausts the launch to
//! [`Error::DependencyFailed`] naming the lost device — exactly the
//! fail-fast surface a zero budget gets. [`GroupSession::fault_counters`]
//! merges every engine's [`crate::sim::FaultCounters`] with the group's
//! own migration bookkeeping.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::analysis::{Diagnostic, GraphReport, InferredWindow, VerifyLevel};
use crate::device::Technology;
use crate::error::{Error, Result};
use crate::memory::{DataRef, Level, MemPlace, MemSpec};
use crate::runtime::parallel;
use crate::sim::{CacheCounters, FaultCounters, FaultPlan, StagingCounters, Time};

use super::engine::{LaunchCheckpoint, LaunchId, LaunchStatus, QueueStats, TierCounters};
use super::marshal::{ArgSpec, PrefetchChoice};
use super::offload::{OffloadOptions, OffloadResult};
use super::prefetch::PrefetchSpec;
use super::session::{OffloadHandle, Session};
use super::{Access, TierChoice, TransferMode};

/// Index of a device within a [`GroupSession`] (attachment order on the
/// [`DeviceGroup`] builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId(
    /// Zero-based attachment index.
    pub usize,
);

/// Builder for a [`GroupSession`]: attach one [`Technology`] per device.
#[derive(Debug, Clone)]
pub struct DeviceGroup {
    devices: Vec<Technology>,
    seed: u64,
    service_threads: usize,
    threads: usize,
    trace_capacity: Option<usize>,
    faults: Vec<(usize, FaultPlan)>,
    verify: VerifyLevel,
}

impl Default for DeviceGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl DeviceGroup {
    /// Empty group; attach devices with [`DeviceGroup::device`].
    pub fn new() -> Self {
        DeviceGroup {
            devices: Vec::new(),
            seed: 42,
            service_threads: 1,
            threads: 1,
            trace_capacity: None,
            faults: Vec::new(),
            verify: VerifyLevel::Off,
        }
    }

    /// Attach one device. The first attached device is `DeviceId(0)`.
    pub fn device(mut self, tech: Technology) -> Self {
        self.devices.push(tech);
        self
    }

    /// Deterministic base seed. Device `i` derives its own service-jitter
    /// seed from it; device 0's derivation is the identity, so a
    /// one-device group reproduces a plain [`Session`] bit-for-bit.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Host service threads per device — a **simulated** quantity: how
    /// many request-service workers the cost model charges against each
    /// device's host bus. Affects virtual time. Not to be confused with
    /// [`DeviceGroup::threads`], the real OS-thread count, which never
    /// does.
    pub fn service_threads(mut self, n: usize) -> Self {
        self.service_threads = n.max(1);
        self
    }

    /// Real OS worker threads for driving the per-device engines
    /// ([`crate::runtime::parallel`]). Default 1 — the serial loop,
    /// byte-identical to the pre-threading code path. Any `n` produces
    /// bit-identical traces, stats, clocks and reports (engine invariant
    /// 14): devices interact only at host-level barriers, and all
    /// cross-thread merges happen there in device-index order. Changes
    /// wall-clock only.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Record a bounded event trace on every device.
    pub fn trace(mut self, capacity: usize) -> Self {
        self.trace_capacity = Some(capacity);
        self
    }

    /// Install a seeded fault schedule on one device (by attachment
    /// index). Core faults strike that device's engine only; a
    /// [`FaultPlan::lose_device`] there makes the group migrate
    /// retry-budgeted launches to surviving devices (module docs,
    /// [`GroupLaunchBuilder::retry`]).
    pub fn faults(mut self, device: usize, plan: FaultPlan) -> Self {
        self.faults.push((device, plan));
        self
    }

    /// Static-verification level applied to **every** per-device session
    /// (the group analogue of [`super::SessionBuilder::verify`]): each
    /// device's engine lints its own launches at submit, and
    /// [`GroupSession::verify_graph`] collects the per-device whole-graph
    /// reports.
    pub fn verify(mut self, level: VerifyLevel) -> Self {
        self.verify = level;
        self
    }

    /// Construct the group session (at least one device required).
    pub fn build(self) -> Result<GroupSession> {
        if self.devices.is_empty() {
            return Err(Error::Coordinator("a device group needs at least one device".into()));
        }
        let mut sessions = Vec::with_capacity(self.devices.len());
        for (i, tech) in self.devices.into_iter().enumerate() {
            let mut b = Session::builder(tech)
                .seed(self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .service_threads(self.service_threads)
                .verify(self.verify);
            if let Some(cap) = self.trace_capacity {
                b = b.trace(cap);
            }
            sessions.push(b.build()?);
        }
        let n = sessions.len();
        for (d, plan) in self.faults {
            let sess = sessions.get_mut(d).ok_or_else(|| {
                Error::Coordinator(format!(
                    "fault plan targets device {d}, but the group has {n} devices"
                ))
            })?;
            sess.engine_mut().install_faults(plan);
        }
        Ok(GroupSession {
            sessions,
            bufs: Vec::new(),
            parked: BTreeMap::new(),
            staging: StagingCounters::default(),
            relaunch: BTreeMap::new(),
            faults: FaultCounters::default(),
            flow_windows: BTreeMap::new(),
            next_seq: 0,
            threads: self.threads,
        })
    }
}

/// A reference to (a window of) a group buffer — the multi-device
/// analogue of [`DataRef`]. Resolve to a device-local view with
/// [`GroupSession::device_ref`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupRef {
    gid: usize,
    offset: usize,
    len: usize,
}

impl GroupRef {
    /// Elements visible through this view.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the view is empty (never true for allocated buffers).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// A sub-view, mirroring [`DataRef::slice`] (panics out of range).
    pub fn slice(&self, offset: usize, len: usize) -> GroupRef {
        assert!(
            offset + len <= self.len,
            "slice [{offset}, {}) out of group view of length {}",
            offset + len,
            self.len
        );
        GroupRef { gid: self.gid, offset: self.offset + offset, len }
    }
}

/// The recorded last writer of a group buffer.
#[derive(Debug, Clone, Copy)]
struct GroupWriter {
    /// Device the writer ran (or would have run) on.
    device: usize,
    /// Engine launch id for submitted writers; the group sequence number
    /// for writers that were parked before ever reaching an engine.
    id: u64,
    /// Whether the writer was parked with a propagated failure and never
    /// submitted (its replica was never written — but its failure must
    /// still reach transitive cross-device dependents).
    parked: bool,
}

/// One replicated group buffer.
struct GroupBuf {
    /// Per-device full-view references (index = device).
    drefs: Vec<DataRef>,
    len: usize,
    /// Which replicas hold the authoritative contents.
    fresh: Vec<bool>,
    /// Device whose launch last wrote the buffer (`None` = host wrote it
    /// last / never written — every replica fresh).
    authoritative: Option<usize>,
    writer: Option<GroupWriter>,
}

/// One argument of a group launch — [`super::ArgSpec`] over [`GroupRef`]s.
#[derive(Debug, Clone)]
pub enum GroupArgSpec {
    /// A host scalar (float).
    Float(f64),
    /// A host scalar (int).
    Int(i64),
    /// A small by-value array copied into the launch message.
    Values(Vec<f64>),
    /// A group-buffer reference argument.
    Ref {
        /// The buffer window.
        gref: GroupRef,
        /// Shard across the launch's cores or broadcast the whole view.
        shard: bool,
        /// Read-only vs mutable (drives both write-back and the group's
        /// authoritative-replica tracking).
        access: Access,
        /// Pre-fetch choice, as for [`super::ArgSpec::Ref`].
        prefetch: PrefetchChoice,
    },
    /// One distinct group reference per core (core-ordered).
    PerCore {
        /// Core-ordered references.
        grefs: Vec<GroupRef>,
        /// Access modifier, applied to each.
        access: Access,
        /// Pre-fetch choice.
        prefetch: PrefetchChoice,
    },
}

impl GroupArgSpec {
    /// Convenience: a sharded read-only reference.
    pub fn sharded(gref: GroupRef) -> GroupArgSpec {
        GroupArgSpec::Ref {
            gref,
            shard: true,
            access: Access::ReadOnly,
            prefetch: PrefetchChoice::Default,
        }
    }

    /// Convenience: a broadcast read-only reference.
    pub fn broadcast(gref: GroupRef) -> GroupArgSpec {
        GroupArgSpec::Ref {
            gref,
            shard: false,
            access: Access::ReadOnly,
            prefetch: PrefetchChoice::Default,
        }
    }

    /// Convenience: a sharded mutable reference.
    pub fn sharded_mut(gref: GroupRef) -> GroupArgSpec {
        GroupArgSpec::Ref {
            gref,
            shard: true,
            access: Access::Mutable,
            prefetch: PrefetchChoice::Default,
        }
    }

    /// The group buffers this argument touches, with the write flag.
    fn flows(&self) -> Vec<(usize, bool)> {
        match self {
            GroupArgSpec::Float(_) | GroupArgSpec::Int(_) | GroupArgSpec::Values(_) => Vec::new(),
            GroupArgSpec::Ref { gref, access, .. } => {
                vec![(gref.gid, *access == Access::Mutable)]
            }
            GroupArgSpec::PerCore { grefs, access, .. } => {
                grefs.iter().map(|g| (g.gid, *access == Access::Mutable)).collect()
            }
        }
    }

    /// The precise view windows behind [`GroupArgSpec::flows`]' whole-buffer
    /// hull: one [`InferredWindow`] per referenced view, in group-buffer
    /// coordinates (`buf` = group buffer id). Staging and freshness keep
    /// hull semantics; these windows are recorded alongside so the static
    /// verifier can tell disjoint sub-views of one buffer apart.
    fn windows(&self) -> Vec<InferredWindow> {
        let win = |g: &GroupRef, access: &Access| InferredWindow {
            buf: g.gid as u64,
            lo: g.offset,
            hi: g.offset + g.len,
            write: *access == Access::Mutable,
            approx: true,
        };
        match self {
            GroupArgSpec::Float(_) | GroupArgSpec::Int(_) | GroupArgSpec::Values(_) => Vec::new(),
            GroupArgSpec::Ref { gref, access, .. } => vec![win(gref, access)],
            GroupArgSpec::PerCore { grefs, access, .. } => {
                grefs.iter().map(|g| win(g, access)).collect()
            }
        }
    }
}

/// Everything needed to resubmit a retry-budgeted group launch on a
/// different device after its original device is permanently lost.
/// Recorded at submit only when the budget is nonzero — fail-fast
/// launches pay nothing.
#[derive(Debug, Clone)]
struct RelaunchSpec {
    kernel: String,
    args: Vec<GroupArgSpec>,
    /// The original core *selection*; what migration must preserve is the
    /// core **count** (checkpoint entries are positional), so the target
    /// runs on its first `len` cores. `None` = every core of the original
    /// device.
    cores: Option<Vec<usize>>,
    mode: TransferMode,
    prefetch: Option<PrefetchSpec>,
    fuel: Option<u64>,
    backoff: Time,
    /// Execution tier of the original submission — migration resumes the
    /// launch on the same tier it started on (checkpoints are
    /// tier-portable, but keeping the tier keeps the accounting honest).
    tier: TierChoice,
}

/// Outcome of making one buffer fresh on the launching device.
enum StageOutcome {
    /// Already fresh — no copy, no cost.
    Fresh,
    /// Staged; the copy completes at this virtual time (activation floor).
    Staged(Time),
    /// The authoritative writer failed; the dependent must be abandoned.
    Poisoned(Error),
}

/// A live session over a group of devices (module docs). Owns one
/// [`Session`] (engine + registry + kernels) per device; group buffers,
/// placement, cross-device staging and failure propagation live here.
pub struct GroupSession {
    sessions: Vec<Session>,
    bufs: Vec<GroupBuf>,
    /// Errors parked for launches abandoned before reaching an engine,
    /// keyed by group sequence number; claimed by the handle's `wait`.
    parked: BTreeMap<u64, Error>,
    staging: StagingCounters,
    /// Resubmission specs for retry-budgeted launches, keyed by group
    /// sequence number; consulted when a device is lost mid-launch.
    relaunch: BTreeMap<u64, RelaunchSpec>,
    /// Group-level fault bookkeeping (migrations and their staged
    /// checkpoint bytes; abandonments the *group* decided). Per-device
    /// injection/retry counts live in each engine and are merged in by
    /// [`GroupSession::fault_counters`].
    faults: FaultCounters,
    /// Precise per-view flow windows recorded at submit, keyed by group
    /// sequence number — the fine-grained record the whole-buffer hulls
    /// (`GroupArgSpec::flows`) collapse away. Staging decisions still use
    /// the hulls; the verifier reads these.
    flow_windows: BTreeMap<u64, Vec<InferredWindow>>,
    next_seq: u64,
    /// OS worker threads for device fan-outs ([`DeviceGroup::threads`]).
    /// 1 = the serial pre-threading path; observables are identical at
    /// any value.
    threads: usize,
}

impl std::fmt::Debug for GroupSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GroupSession")
            .field("devices", &self.sessions.len())
            .field("bufs", &self.bufs.len())
            .field("staging", &self.staging)
            .finish()
    }
}

impl GroupSession {
    /// Builder entry point (alias for [`DeviceGroup::new`]).
    pub fn builder() -> DeviceGroup {
        DeviceGroup::new()
    }

    /// Number of attached devices.
    pub fn devices(&self) -> usize {
        self.sessions.len()
    }

    /// Configured OS worker-thread count ([`DeviceGroup::threads`]).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Change the OS worker-thread count mid-session. Safe at any point:
    /// thread count is not part of any seed or cost model, so this can
    /// never change an observable (engine invariant 14) — only how many
    /// devices make progress concurrently at the next fan-out.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
    }

    /// Technology of one device.
    pub fn tech(&self, d: DeviceId) -> &Technology {
        self.sessions[d.0].tech()
    }

    /// The underlying per-device session (stats, trace, engine knobs).
    pub fn session(&self, d: DeviceId) -> &Session {
        &self.sessions[d.0]
    }

    /// Mutable per-device session access — the escape hatch for
    /// device-*private* state (e.g. `Shared`/`Microcore` allocations,
    /// service-bandwidth knobs). Device-private variables never cross
    /// devices; only group buffers do.
    pub fn session_mut(&mut self, d: DeviceId) -> &mut Session {
        &mut self.sessions[d.0]
    }

    /// The group's virtual clock: the latest completion watermark across
    /// the devices' shared timeline.
    pub fn now(&self) -> Time {
        self.sessions.iter().map(Session::now).max().unwrap_or(0)
    }

    /// Cross-device staging audit (module docs).
    pub fn staging_counters(&self) -> StagingCounters {
        self.staging
    }

    /// Fault/recovery accounting for the whole group: every device
    /// engine's counters merged with the group's own migration
    /// bookkeeping (launches migrated off lost devices, their staged
    /// checkpoint bytes, and migration abandonments).
    pub fn fault_counters(&self) -> FaultCounters {
        let mut total = self.faults;
        for s in &self.sessions {
            total.merge(&s.fault_counters());
        }
        total
    }

    /// Aggregate cache accounting across every device's live variables —
    /// the group-wide view of the shared host-level cache tier.
    pub fn total_cache_counters(&self) -> CacheCounters {
        let mut total = CacheCounters::default();
        for s in &self.sessions {
            total.merge(&s.total_cache_counters());
        }
        total
    }

    /// Launches submitted but not yet complete, summed over devices.
    pub fn in_flight(&self) -> usize {
        self.sessions.iter().map(Session::in_flight).sum()
    }

    /// Per-stage launch-table breakdown summed over every device engine
    /// ([`QueueStats::merge`] of each session's
    /// [`Session::queue_stats`]) — the pool-wide saturation signal the
    /// fleet scheduler and the fairness tests read. `busy_cores` says how
    /// *full* one device is; this says *why* the group's remaining
    /// launches aren't running (edge-blocked vs core-contended).
    pub fn queue_stats(&self) -> QueueStats {
        let mut total = QueueStats::default();
        for s in &self.sessions {
            total.merge(&s.queue_stats());
        }
        total
    }

    /// Per-tier execution accounting summed over every device engine
    /// ([`TierCounters::merge`] of each session's
    /// [`Session::tier_counters`]).
    pub fn tier_counters(&self) -> TierCounters {
        let mut total = TierCounters::default();
        for s in &self.sessions {
            total.merge(&s.tier_counters());
        }
        total
    }

    /// Allocate a group buffer: one replica per device, identical
    /// contents. Group buffers must live at the **Host level or above**
    /// (plain [`MemPlace::Host`] or cache-fronted
    /// [`MemPlace::Cached`]) — the staging invariant; device-private
    /// places are allocated per device via [`GroupSession::session_mut`].
    pub fn alloc(&mut self, spec: MemSpec) -> Result<GroupRef> {
        match spec.place() {
            MemPlace::Host | MemPlace::Cached(_) => {}
            other => {
                return Err(Error::Memory(format!(
                    "group buffer '{}' must live at Host level or above (the staging \
                     invariant — no device reads another device's local window); \
                     {other:?} is device-private: allocate it on one device via \
                     session_mut(d)",
                    spec.name()
                )))
            }
        }
        let mut drefs = Vec::with_capacity(self.sessions.len());
        for sess in self.sessions.iter_mut() {
            drefs.push(sess.alloc(spec.clone())?);
        }
        let len = drefs[0].len;
        let gid = self.bufs.len();
        let n = self.sessions.len();
        self.bufs.push(GroupBuf {
            drefs,
            len,
            fresh: vec![true; n],
            authoritative: None,
            writer: None,
        });
        Ok(GroupRef { gid, offset: 0, len })
    }

    /// Resolve a group reference to one device's local view.
    pub fn device_ref(&self, gref: GroupRef, d: DeviceId) -> Result<DataRef> {
        let buf = self
            .bufs
            .get(gref.gid)
            .ok_or_else(|| Error::Memory(format!("unknown group buffer {}", gref.gid)))?;
        if d.0 >= self.sessions.len() {
            return Err(Error::Coordinator(format!(
                "device {} out of range (group has {} devices)",
                d.0,
                self.sessions.len()
            )));
        }
        Ok(buf.drefs[d.0].slice(gref.offset, gref.len))
    }

    /// Read a group buffer's (view's) contents host-side, from the
    /// authoritative replica, after quiescing that device's in-flight
    /// launches touching it.
    pub fn read(&mut self, gref: GroupRef) -> Result<Vec<f32>> {
        let s = self.bufs[gref.gid].authoritative.unwrap_or(0);
        let dref = self.device_ref(gref, DeviceId(s))?;
        self.sessions[s].quiesce(dref)?;
        self.sessions[s].read(dref)
    }

    /// Write into a group buffer host-side: every replica receives the
    /// data (write-all coherence). A write covering the **whole** buffer
    /// marks every replica fresh and clears the recorded writer — this is
    /// also how a poisoned buffer (failed writer) is reset. A partial
    /// write leaves the staleness tracking untouched (stale replicas got
    /// the host values too, but remain stale overall). As with
    /// [`Session::write`], ordering against in-flight launches is the
    /// caller's via waits/quiesce.
    pub fn write(&mut self, gref: GroupRef, off: usize, data: &[f32]) -> Result<()> {
        for d in 0..self.sessions.len() {
            let dref = self.device_ref(gref, DeviceId(d))?;
            self.sessions[d].write(dref, off, data)?;
        }
        let buf = &mut self.bufs[gref.gid];
        if gref.offset == 0 && off == 0 && data.len() == buf.len {
            buf.fresh.iter_mut().for_each(|f| *f = true);
            buf.authoritative = None;
            buf.writer = None;
        }
        Ok(())
    }

    /// Compile and register a kernel on every device (one name, N
    /// programs — each device compiles its own copy).
    pub fn compile_kernel(&mut self, name: &str, src: &str) -> Result<()> {
        for s in self.sessions.iter_mut() {
            s.compile_kernel(name, src)?;
        }
        Ok(())
    }

    /// Begin building a group launch of the named kernel. Configure with
    /// the usual builder surface plus [`GroupLaunchBuilder::on`]; without
    /// `.on(..)` the launch is placed automatically on the least-occupied
    /// device.
    pub fn launch_named(&mut self, name: &str) -> Result<GroupLaunchBuilder<'_>> {
        self.sessions[0].kernel(name)?; // existence check before building
        Ok(GroupLaunchBuilder {
            group: self,
            kernel: name.to_string(),
            device: None,
            cores: None,
            args: Vec::new(),
            mode: TransferMode::OnDemand,
            prefetch: None,
            fuel: None,
            after: Vec::new(),
            retry: 0,
            backoff: 0,
            tenant: None,
            tier: TierChoice::Interp,
        })
    }

    /// Drive the group until `handle`'s launch completes; claim its
    /// result or error (equivalently [`GroupHandle::wait`]).
    pub fn wait(&mut self, handle: GroupHandle) -> Result<OffloadResult> {
        handle.wait_inner(self)
    }

    /// Drive every device until all submitted launches complete (or
    /// fail). Parked outcomes — including group-level `DependencyFailed`
    /// errors — stay claimable by their handles' `wait`.
    ///
    /// This is the group's main parallel section: all cross-device
    /// interaction happened at submit (staging copies, quiesces), so
    /// between here and completion the devices are share-nothing and
    /// each drains on its own worker thread
    /// ([`crate::runtime::parallel::run_indexed`]). Results merge in
    /// device-index order; at `threads <= 1` this is the literal serial
    /// loop. Either way the first error by device index is returned
    /// (`wait_all` errors indicate a scheduler invariant violation and
    /// are unreachable in normal operation — real launch failures park
    /// on handles instead).
    pub fn wait_all(&mut self) -> Result<()> {
        if self.threads <= 1 {
            for s in self.sessions.iter_mut() {
                s.wait_all()?;
            }
            return Ok(());
        }
        for r in parallel::run_indexed(self.threads, &mut self.sessions, |_, s| s.wait_all()) {
            r?;
        }
        Ok(())
    }

    /// Whole-graph static pre-flight across every device: each engine
    /// re-derives its edge set from inferred flows and diffs it against
    /// the declared-flow edges, exactly as [`Session::verify_graph`].
    /// Cross-device ordering is staging copies (never engine edges), so
    /// the group report is the per-device reports side by side.
    /// Each device's pre-flight is independent (it reads only that
    /// engine's launch table), so the reports are produced on worker
    /// threads and merged in device-index order.
    pub fn verify_graph(&mut self) -> Vec<(DeviceId, GraphReport)> {
        parallel::run_indexed(self.threads, &mut self.sessions, |d, s| {
            (DeviceId(d), s.verify_graph())
        })
    }

    /// Drain the submit-time diagnostics accumulated on every device's
    /// engine (group analogue of [`Session::take_diagnostics`]), tagged
    /// with the device each was produced on.
    pub fn take_diagnostics(&mut self) -> Vec<(DeviceId, Diagnostic)> {
        let mut out = Vec::new();
        for (d, s) in self.sessions.iter_mut().enumerate() {
            for diag in s.take_diagnostics() {
                out.push((DeviceId(d), diag));
            }
        }
        out
    }

    /// The precise per-view flow windows recorded when group launch `seq`
    /// was submitted (group-buffer coordinates; `buf` = group buffer id).
    /// The whole-buffer hulls drive staging and freshness; this is the
    /// fine-grained record kept alongside them. `None` for unknown
    /// sequence numbers.
    pub fn flow_windows(&self, seq: u64) -> Option<&[InferredWindow]> {
        self.flow_windows.get(&seq).map(Vec::as_slice)
    }

    /// Quiesce every device for a group buffer: drive until no in-flight
    /// launch on any device can touch its replica — the group-wide form
    /// of [`Session::quiesce`].
    /// Like [`GroupSession::wait_all`], the per-device drains are
    /// independent once the views are resolved, so they run on worker
    /// threads with errors surfacing in device-index order.
    pub fn quiesce(&mut self, gref: GroupRef) -> Result<()> {
        let mut drefs = Vec::with_capacity(self.sessions.len());
        for d in 0..self.sessions.len() {
            drefs.push(self.device_ref(gref, DeviceId(d))?);
        }
        if self.threads <= 1 {
            for (d, &dref) in drefs.iter().enumerate() {
                self.sessions[d].quiesce(dref)?;
            }
            return Ok(());
        }
        let drefs = &drefs;
        for r in parallel::run_indexed(self.threads, &mut self.sessions, |d, s| s.quiesce(drefs[d]))
        {
            r?;
        }
        Ok(())
    }

    /// Drive a device until `h` completes, migrating across device loss:
    /// the loop behind [`GroupHandle::wait`]. Same-device retries are the
    /// engine's business; the group steps in only when the whole device
    /// is gone, the failure was transient, and retry budget remains — it
    /// harvests the checkpoint, migrates, and keeps waiting on the new
    /// device (loss can strike more than once). Anything else surfaces
    /// unchanged.
    fn wait_recovering(
        &mut self,
        seq: u64,
        mut device: usize,
        mut h: OffloadHandle,
    ) -> Result<OffloadResult> {
        loop {
            let err = match self.sessions[device].wait(h) {
                Ok(r) => {
                    self.relaunch.remove(&seq);
                    return Ok(r);
                }
                Err(e) => e,
            };
            // A non-transient error (the kernel itself failed) must not
            // migrate; a transient fault on a *live* device already spent
            // its engine-side budget.
            if !err.is_transient() || self.sessions[device].engine().device_lost().is_none() {
                self.relaunch.remove(&seq);
                return Err(err);
            }
            let lost_launch = h.id();
            let Some((ck, left)) =
                self.sessions[device].engine_mut().harvest_checkpoint(lost_launch)
            else {
                // No budget remained at loss — fail exactly as today.
                self.relaunch.remove(&seq);
                return Err(err);
            };
            let Some(spec) = self.relaunch.get(&seq).cloned() else {
                self.relaunch.remove(&seq);
                return Err(err);
            };
            match self.migrate(seq, device, lost_launch.raw(), ck, left, &spec) {
                Ok((target, handle)) => {
                    device = target;
                    h = handle;
                }
                Err(e) => {
                    self.relaunch.remove(&seq);
                    return Err(e);
                }
            }
        }
    }

    /// Move a rescued launch onto a surviving device: pick the
    /// least-occupied survivor with enough cores (ties to the lower
    /// index; checkpoint entries are positional, so the core count is
    /// preserved and the target runs on its first `n` cores), stage the
    /// checkpoint through Host level, re-freshen the launch's
    /// group-buffer inputs on the target, and resubmit with the remaining
    /// budget. No capable survivor exhausts the launch to
    /// [`Error::DependencyFailed`] naming the lost device.
    fn migrate(
        &mut self,
        seq: u64,
        lost: usize,
        lost_launch: u64,
        ck: Option<LaunchCheckpoint>,
        left: u32,
        spec: &RelaunchSpec,
    ) -> Result<(usize, OffloadHandle)> {
        let needed = spec.cores.as_ref().map_or(self.sessions[lost].tech().cores, Vec::len);
        let mut target: Option<usize> = None;
        let mut best_frac = f64::INFINITY;
        for (i, s) in self.sessions.iter().enumerate() {
            if s.engine().device_lost().is_some() || s.tech().cores < needed {
                continue;
            }
            let frac = s.busy_cores() as f64 / s.tech().cores as f64;
            if frac < best_frac {
                best_frac = frac;
                target = Some(i);
            }
        }
        let Some(t) = target else {
            self.faults.abandoned += 1;
            return Err(Error::DependencyFailed {
                launch: seq,
                dep: lost_launch,
                dep_device: Some(self.sessions[lost].tech().name.to_string()),
            });
        };

        // Stage the checkpoint itself at Host level: loss kills cores,
        // not host windows, so the lost device's service charges the read
        // and the survivor's the write — audited like any staging copy.
        let mut floor: Time = 0;
        if let Some(k) = &ck {
            let bytes = k.bytes();
            let t_src = self.sessions[lost].now();
            let read_done =
                self.sessions[lost].engine_mut().service_mut().service(t_src, Level::Host, bytes);
            let t_dst = self.sessions[t].now().max(read_done);
            let write_done =
                self.sessions[t].engine_mut().service_mut().service(t_dst, Level::Host, bytes);
            self.staging.copies += 1;
            self.staging.bytes += bytes;
            self.staging.src_reads += 1;
            self.staging.dst_writes += 1;
            self.faults.checkpoint_bytes += bytes;
            floor = write_done;
        }

        // Group-buffer inputs must be fresh on the target — including
        // buffers this launch itself had begun writing (the recovering
        // exemption on the poison check — see `ensure_fresh`).
        let mut flows: Vec<(usize, bool)> = Vec::new();
        for a in &spec.args {
            for (gid, write) in a.flows() {
                match flows.iter_mut().find(|(g, _)| *g == gid) {
                    Some((_, w)) => *w |= write,
                    None => flows.push((gid, write)),
                }
            }
        }
        for &(gid, _) in &flows {
            match self.ensure_fresh(gid, t, seq, Some((lost, lost_launch)))? {
                StageOutcome::Fresh => {}
                StageOutcome::Staged(done) => floor = floor.max(done),
                StageOutcome::Poisoned(e) => {
                    self.faults.abandoned += 1;
                    return Err(e);
                }
            }
        }

        let dev_args: Vec<ArgSpec> =
            spec.args.iter().map(|a| self.resolve_arg(a, t)).collect::<Result<Vec<_>>>()?;
        let mut options = OffloadOptions::default()
            .transfer(spec.mode)
            .not_before(floor)
            .retry(left.saturating_sub(1))
            .backoff(spec.backoff)
            .tier(spec.tier);
        if let Some(p) = spec.prefetch.clone() {
            options = options.prefetch(p);
        }
        if let Some(f) = spec.fuel {
            options = options.fuel(f);
        }
        options.restore = ck.map(Rc::new);
        let handle = self.sessions[t]
            .launch_named(&spec.kernel)?
            .args(&dev_args)
            .options(options)
            .cores((0..needed).collect())
            .submit()?;
        for &(gid, write) in &flows {
            if write {
                self.record_writer(gid, t, handle.id().raw());
            }
        }
        self.faults.migrated += 1;
        Ok((t, handle))
    }

    /// Automatic placement: the device with the lowest busy-core
    /// fraction; ties go to the lower index (deterministic). A lost
    /// device never receives new work (submitting there would only
    /// abandon the launch on arrival); with every device lost the fall
    /// back is device 0, whose engine fails the launch immediately.
    fn place(&self) -> usize {
        let mut best = 0;
        let mut best_frac = f64::INFINITY;
        for (i, s) in self.sessions.iter().enumerate() {
            if s.engine().device_lost().is_some() {
                continue;
            }
            let frac = s.busy_cores() as f64 / s.tech().cores as f64;
            if frac < best_frac {
                best_frac = frac;
                best = i;
            }
        }
        best
    }

    /// Make buffer `gid` fresh on device `d` (module docs: quiesce both
    /// ends, refuse a failed writer, charge one host-level read + one
    /// host-level write, return the copy's completion as the activation
    /// floor). `recovering` names a `(device, engine launch id)` being
    /// migrated off a lost device: that launch is its own recorded writer
    /// for buffers it had begun mutating, and although it *failed* on the
    /// lost engine, staging its partial pre-checkpoint writes out of the
    /// lost device's host-level replica is exactly the recovery path — so
    /// it is exempt from the poison check (deterministic replay re-issues
    /// the missing writes idempotently).
    fn ensure_fresh(
        &mut self,
        gid: usize,
        d: usize,
        seq: u64,
        recovering: Option<(usize, u64)>,
    ) -> Result<StageOutcome> {
        if self.bufs[gid].fresh[d] {
            return Ok(StageOutcome::Fresh);
        }
        let s = self.bufs[gid]
            .authoritative
            .expect("a stale replica implies an authoritative device");
        let (src, dst, len) = {
            let buf = &self.bufs[gid];
            (buf.drefs[s], buf.drefs[d], buf.len)
        };
        // RAW: the writer (and everything else touching the source
        // replica) finishes before the host-side read. WAR: in-flight
        // readers of the destination replica finish before the overwrite.
        self.sessions[s].quiesce(src)?;
        self.sessions[d].quiesce(dst)?;
        if let Some(w) = self.bufs[gid].writer {
            let exempt =
                recovering.is_some_and(|(dev, id)| !w.parked && w.device == dev && w.id == id);
            let failed = !exempt
                && (w.parked
                    || self.sessions[w.device].engine().launch_failed(LaunchId::from_raw(w.id)));
            if failed {
                return Ok(StageOutcome::Poisoned(Error::DependencyFailed {
                    launch: seq,
                    dep: w.id,
                    dep_device: Some(self.sessions[w.device].tech().name.to_string()),
                }));
            }
        }
        let bytes = (len * 4) as u64;
        // Cost levels probed through the registry *before* the accesses
        // (engine invariant 5): a cache-fronted source resident in its
        // shared window is charged at Shared read cost.
        let src_level = self.sessions[s].engine().registry().access_level(src, 0, len)?;
        let dst_level = self.sessions[d].engine().registry().access_level(dst, 0, len)?;
        let t_src = self.sessions[s].now();
        let read_done =
            self.sessions[s].engine_mut().service_mut().service(t_src, src_level, bytes);
        let t_dst = self.sessions[d].now().max(read_done);
        let write_done =
            self.sessions[d].engine_mut().service_mut().service(t_dst, dst_level, bytes);
        let data = self.sessions[s].read(src)?;
        self.sessions[d].write(dst, 0, &data)?;
        self.staging.copies += 1;
        self.staging.bytes += bytes;
        self.staging.src_reads += 1;
        self.staging.dst_writes += 1;
        self.bufs[gid].fresh[d] = true;
        Ok(StageOutcome::Staged(write_done))
    }

    /// Record a *submitted* launch as the writer of a buffer: its device
    /// becomes the authoritative replica (engine semantics keep even a
    /// failing launch's stamped effects, so the replica is the current
    /// data either way).
    fn record_writer(&mut self, gid: usize, d: usize, id: u64) {
        let buf = &mut self.bufs[gid];
        buf.authoritative = Some(d);
        for (i, f) in buf.fresh.iter_mut().enumerate() {
            *f = i == d;
        }
        buf.writer = Some(GroupWriter { device: d, id, parked: false });
    }

    /// Record a *parked* (never-submitted) launch as a buffer's failed
    /// writer. Nothing ran, so replica contents and freshness stay
    /// exactly as they were — only the writer slot is poisoned: a
    /// successor that must *stage* from this buffer is abandoned in
    /// turn, while a successor whose replica is already fresh proceeds
    /// on the data as it is (the blocking-continue rule).
    fn record_parked_writer(&mut self, gid: usize, d: usize, seq: u64) {
        self.bufs[gid].writer = Some(GroupWriter { device: d, id: seq, parked: true });
    }

    /// Resolve one group argument into a device-local [`ArgSpec`].
    fn resolve_arg(&self, a: &GroupArgSpec, d: usize) -> Result<ArgSpec> {
        Ok(match a {
            GroupArgSpec::Float(v) => ArgSpec::Float(*v),
            GroupArgSpec::Int(v) => ArgSpec::Int(*v),
            GroupArgSpec::Values(v) => ArgSpec::Values(v.clone()),
            GroupArgSpec::Ref { gref, shard, access, prefetch } => ArgSpec::Ref {
                dref: self.device_ref(*gref, DeviceId(d))?,
                shard: *shard,
                access: *access,
                prefetch: *prefetch,
            },
            GroupArgSpec::PerCore { grefs, access, prefetch } => ArgSpec::PerCore {
                drefs: grefs
                    .iter()
                    .map(|g| self.device_ref(*g, DeviceId(d)))
                    .collect::<Result<Vec<_>>>()?,
                access: *access,
                prefetch: *prefetch,
            },
        })
    }
}

/// Builder for one group launch (from [`GroupSession::launch_named`]).
#[derive(Debug)]
pub struct GroupLaunchBuilder<'g> {
    group: &'g mut GroupSession,
    kernel: String,
    device: Option<DeviceId>,
    cores: Option<Vec<usize>>,
    args: Vec<GroupArgSpec>,
    mode: TransferMode,
    prefetch: Option<PrefetchSpec>,
    fuel: Option<u64>,
    after: Vec<GroupHandle>,
    retry: u32,
    backoff: Time,
    tenant: Option<u64>,
    tier: TierChoice,
}

impl GroupLaunchBuilder<'_> {
    /// Pin the launch to a device (default: automatic placement by
    /// per-device occupancy).
    pub fn on(mut self, device: DeviceId) -> Self {
        self.device = Some(device);
        self
    }

    /// Restrict to a core subset *of the chosen device* (default: all of
    /// that device's cores). Validated at submit against the device's
    /// [`Technology::validate_cores`] — whose message names the device.
    pub fn cores(mut self, cores: Vec<usize>) -> Self {
        self.cores = Some(cores);
        self
    }

    /// Append one argument.
    pub fn arg(mut self, arg: GroupArgSpec) -> Self {
        self.args.push(arg);
        self
    }

    /// Append a slice of arguments.
    pub fn args(mut self, args: &[GroupArgSpec]) -> Self {
        self.args.extend_from_slice(args);
        self
    }

    /// Set the argument transfer mode.
    pub fn mode(mut self, mode: TransferMode) -> Self {
        self.mode = mode;
        self
    }

    /// Set the default pre-fetch annotation (switches the mode to
    /// [`TransferMode::Prefetch`]).
    pub fn prefetch(mut self, spec: PrefetchSpec) -> Self {
        self.prefetch = Some(spec);
        self
    }

    /// Set the per-core dispatch budget.
    pub fn fuel(mut self, fuel: u64) -> Self {
        self.fuel = Some(fuel);
        self
    }

    /// Transient-fault retry budget ([`super::OffloadOptions::retry`]).
    /// Besides the engine's same-device checkpoint/retry, a budgeted
    /// group launch whose device is permanently *lost* **migrates**: its
    /// harvested checkpoint is staged through Host level and resumed on
    /// the best surviving device (module docs). Default 0 = fail-fast.
    pub fn retry(mut self, n: u32) -> Self {
        self.retry = n;
        self
    }

    /// Virtual-time back-off before each same-device retry requeue
    /// ([`super::OffloadOptions::backoff`]).
    pub fn backoff(mut self, t: Time) -> Self {
        self.backoff = t;
        self
    }

    /// Tag the launch with its owning tenant
    /// ([`super::OffloadOptions::tenant`] — fleet bookkeeping only, never
    /// scheduling).
    pub fn tenant(mut self, tenant: u64) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// Select the launch's execution tier
    /// ([`super::OffloadOptions::tier`]): interpreter (default), compiled
    /// linear IR, or `Auto`. Bit-identical results either way; a
    /// retry-budgeted launch migrated to another device resumes on the
    /// same tier.
    pub fn tier(mut self, tier: TierChoice) -> Self {
        self.tier = tier;
        self
    }

    /// Add an explicit dependency edge on an earlier group launch.
    /// Explicit edges live inside one engine, so the dependency must be
    /// on the **same device** as this launch (cross-device ordering is
    /// expressed by data flow — the staging copy *is* the edge). An
    /// *unpinned* launch with explicit edges is therefore placed on its
    /// first dependency's device rather than by occupancy; a `.on(..)`
    /// (or a second edge) naming a different device is rejected at
    /// submit. The edge itself is handed to the engine's launch graph
    /// verbatim.
    pub fn after(mut self, dep: GroupHandle) -> Self {
        self.after.push(dep);
        self
    }

    /// Resolve placement, stage stale cross-device inputs, and submit to
    /// the chosen device's engine. Returns without driving any timeline
    /// beyond the quiesces staging requires.
    pub fn submit(self) -> Result<GroupHandle> {
        let GroupLaunchBuilder {
            group,
            kernel,
            device,
            cores,
            args,
            mode,
            prefetch,
            fuel,
            after,
            retry,
            backoff,
            tenant,
            tier,
        } = self;
        let d = match device {
            Some(dev) => {
                if dev.0 >= group.sessions.len() {
                    return Err(Error::Coordinator(format!(
                        "device {} out of range (group has {} devices)",
                        dev.0,
                        group.sessions.len()
                    )));
                }
                dev.0
            }
            // An explicit edge pins placement: the edge lives inside one
            // engine, so an unpinned dependent follows its dependency
            // instead of the occupancy heuristic (which could otherwise
            // split them across devices unpredictably).
            None => match after.first() {
                Some(dep) => dep.device.0,
                None => group.place(),
            },
        };
        let seq = group.next_seq;
        group.next_seq += 1;

        // The launch's group-level flow set: buffers touched, write flag
        // OR-ed per buffer (the whole-buffer hull — module docs). The
        // precise per-view windows the hull collapses are recorded
        // alongside, keyed by sequence number, for the static verifier.
        let mut flows: Vec<(usize, bool)> = Vec::new();
        let mut windows: Vec<InferredWindow> = Vec::new();
        for a in &args {
            for (gid, write) in a.flows() {
                match flows.iter_mut().find(|(g, _)| *g == gid) {
                    Some((_, w)) => *w |= write,
                    None => flows.push((gid, write)),
                }
            }
            windows.extend(a.windows());
        }
        group.flow_windows.insert(seq, windows);

        // Cross-device staging (+ failure propagation) for stale inputs.
        let mut not_before: Time = 0;
        let mut parked: Option<Error> = None;
        for &(gid, _) in &flows {
            match group.ensure_fresh(gid, d, seq, None)? {
                StageOutcome::Fresh => {}
                StageOutcome::Staged(t) => not_before = not_before.max(t),
                StageOutcome::Poisoned(e) => {
                    parked = Some(e);
                    break;
                }
            }
        }

        // Explicit same-device edges (validated against placement).
        let mut engine_after: Vec<LaunchId> = Vec::new();
        for dep in &after {
            if dep.device.0 != d {
                return Err(Error::Coordinator(format!(
                    "explicit .after edge crosses devices ({} -> {}): cross-device \
                     ordering comes from data flow (the staging copy is the edge)",
                    group.sessions[dep.device.0].tech().name,
                    group.sessions[d].tech().name,
                )));
            }
            match dep.inner {
                Some(h) => engine_after.push(h.id()),
                // An explicit edge on a parked (never-submitted) launch
                // abandons this one — the engine's explicit-edge rule.
                None => {
                    parked.get_or_insert(Error::DependencyFailed {
                        launch: seq,
                        dep: dep.seq,
                        dep_device: Some(group.sessions[dep.device.0].tech().name.to_string()),
                    });
                }
            }
        }

        if let Some(e) = parked {
            group.parked.insert(seq, e);
            // Poison this launch's outputs (writer slot only — replica
            // contents and freshness are untouched, nothing ran) so the
            // abandonment propagates across later *staging* edges.
            for &(gid, write) in &flows {
                if write {
                    group.record_parked_writer(gid, d, seq);
                }
            }
            return Ok(GroupHandle { seq, device: DeviceId(d), inner: None });
        }

        let dev_args: Vec<ArgSpec> =
            args.iter().map(|a| group.resolve_arg(a, d)).collect::<Result<Vec<_>>>()?;
        // A nonzero budget records everything migration would need to
        // resubmit this launch elsewhere; fail-fast launches record
        // nothing.
        let relaunch = (retry > 0).then(|| RelaunchSpec {
            kernel: kernel.clone(),
            args: args.clone(),
            cores: cores.clone(),
            mode,
            prefetch: prefetch.clone(),
            fuel,
            backoff,
            tier,
        });
        let mut options = OffloadOptions::default()
            .transfer(mode)
            .not_before(not_before)
            .retry(retry)
            .backoff(backoff)
            .tier(tier);
        if let Some(p) = prefetch {
            options = options.prefetch(p);
        }
        if let Some(f) = fuel {
            options = options.fuel(f);
        }
        if let Some(t) = tenant {
            options = options.tenant(t);
        }
        for id in engine_after {
            options = options.after(id);
        }
        let mut builder = group.sessions[d].launch_named(&kernel)?.args(&dev_args).options(options);
        if let Some(cs) = cores {
            builder = builder.cores(cs);
        }
        let h = builder.submit()?;
        if let Some(spec) = relaunch {
            group.relaunch.insert(seq, spec);
        }
        for &(gid, write) in &flows {
            if write {
                group.record_writer(gid, d, h.id().raw());
            }
        }
        Ok(GroupHandle { seq, device: DeviceId(d), inner: Some(h) })
    }
}

/// A claim ticket for a group launch: plain `Copy` data carrying the
/// placement decision. Redeem with [`GroupHandle::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupHandle {
    seq: u64,
    device: DeviceId,
    inner: Option<OffloadHandle>,
}

impl GroupHandle {
    /// The device the launch was placed on (pinned or automatic).
    pub fn device(&self) -> DeviceId {
        self.device
    }

    /// Group sequence number (submission order across all devices) — the
    /// key [`GroupSession::flow_windows`] records precise flow windows
    /// under.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Drive the group until this launch completes; claim its result —
    /// or the error that killed it, including a cross-device
    /// [`Error::DependencyFailed`] naming the failed writer's device.
    pub fn wait(self, group: &mut GroupSession) -> Result<OffloadResult> {
        self.wait_inner(group)
    }

    fn wait_inner(self, group: &mut GroupSession) -> Result<OffloadResult> {
        if let Some(e) = group.parked.remove(&self.seq) {
            return Err(e);
        }
        match self.inner {
            Some(h) => group.wait_recovering(self.seq, self.device.0, h),
            None => Err(Error::Coordinator(format!(
                "group launch {} is unknown or already waited",
                self.seq
            ))),
        }
    }

    /// Lifecycle stage on the owning device's engine; parked launches
    /// report `Completed` (their error is ready to claim). `None` once
    /// waited.
    pub fn status(&self, group: &GroupSession) -> Option<LaunchStatus> {
        if group.parked.contains_key(&self.seq) {
            return Some(LaunchStatus::Completed);
        }
        self.inner.and_then(|h| h.status(&group.sessions[self.device.0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::CacheSpec;

    const SUM_SRC: &str = r#"
def total(xs):
    s = 0.0
    i = 0
    while i < len(xs):
        s += xs[i]
        i += 1
    return s
"#;

    const FILL_SRC: &str = r#"
def fill(a, v):
    i = 0
    while i < len(a):
        a[i] = v + i
        i += 1
    return 0
"#;

    fn two_epiphanies() -> GroupSession {
        GroupSession::builder()
            .device(Technology::epiphany3())
            .device(Technology::epiphany3())
            .seed(9)
            .build()
            .unwrap()
    }

    #[test]
    fn group_needs_a_device_and_host_level_buffers() {
        assert!(GroupSession::builder().build().is_err());
        let mut g = two_epiphanies();
        assert_eq!(g.devices(), 2);
        assert!(g.alloc(MemSpec::host("a").zeroed(16)).is_ok());
        assert!(g.alloc(MemSpec::cached("c", CacheSpec { segment_elems: 8, capacity_segments: 2 }).zeroed(16)).is_ok());
        let err = g.alloc(MemSpec::shared("s").zeroed(16)).unwrap_err().to_string();
        assert!(err.contains("staging invariant"), "{err}");
        assert!(g.alloc(MemSpec::microcore("m").zeroed(8)).is_err());
    }

    #[test]
    fn host_writes_replicate_and_reads_see_them() {
        let mut g = two_epiphanies();
        let a = g.alloc(MemSpec::host("a").zeroed(8)).unwrap();
        g.write(a, 0, &[1.0; 8]).unwrap();
        assert_eq!(g.read(a).unwrap(), vec![1.0; 8]);
        for d in 0..2 {
            let dref = g.device_ref(a, DeviceId(d)).unwrap();
            assert_eq!(g.session(DeviceId(d)).read(dref).unwrap(), vec![1.0; 8]);
        }
        // Slices compose like DataRef slices.
        assert_eq!(a.slice(2, 3).len(), 3);
    }

    #[test]
    fn group_queue_stats_sums_every_device_engine() {
        let mut g = two_epiphanies();
        let b0 = g.alloc(MemSpec::host("b0").zeroed(32)).unwrap();
        let b1 = g.alloc(MemSpec::host("b1").zeroed(32)).unwrap();
        g.compile_kernel("fill", FILL_SRC).unwrap();
        g.compile_kernel("total", SUM_SRC).unwrap();
        // Device 0: a writer plus a dependent reader (inferred RAW edge,
        // so the reader sits blocked); device 1: an independent writer.
        // Nothing is driven yet — submission never advances time.
        let f0 = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(b0), GroupArgSpec::Float(1.0)])
            .on(DeviceId(0))
            .submit()
            .unwrap();
        let t0 = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(b0))
            .on(DeviceId(0))
            .submit()
            .unwrap();
        let f1 = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(b1), GroupArgSpec::Float(2.0)])
            .on(DeviceId(1))
            .submit()
            .unwrap();
        let qs = g.queue_stats();
        assert_eq!(qs, QueueStats { blocked: 1, pending: 2, active: 0, completed: 0 });
        assert_eq!(qs.blocked + qs.pending + qs.active, g.in_flight());
        // Waiting the reader drives device 0 to completion: its writer's
        // outcome parks unclaimed (completed), device 1 stays pending.
        g.wait(t0).unwrap();
        assert_eq!(g.queue_stats(), QueueStats { blocked: 0, pending: 1, active: 0, completed: 1 });
        // Claiming everything empties both launch tables.
        g.wait(f0).unwrap();
        g.wait(f1).unwrap();
        assert_eq!(g.queue_stats(), QueueStats::default());
    }

    #[test]
    fn precise_flow_windows_recorded_alongside_buffer_hulls() {
        let mut g = GroupSession::builder()
            .device(Technology::epiphany3())
            .device(Technology::epiphany3())
            .seed(9)
            .verify(VerifyLevel::Warn)
            .build()
            .unwrap();
        let a = g.alloc(MemSpec::host("a").zeroed(32)).unwrap();
        g.compile_kernel("fill", FILL_SRC).unwrap();
        g.compile_kernel("total", SUM_SRC).unwrap();
        // Disjoint halves of one buffer: the whole-buffer hull sees one
        // (gid, write) entry per launch, but the recorded windows keep the
        // halves apart.
        let lo_half = a.slice(0, 16);
        let hi_half = a.slice(16, 16);
        let w = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(lo_half), GroupArgSpec::Float(1.0)])
            .on(DeviceId(0))
            .submit()
            .unwrap();
        let r = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(hi_half))
            .on(DeviceId(0))
            .submit()
            .unwrap();
        let ww = g.flow_windows(w.seq).unwrap();
        assert_eq!(ww.len(), 1);
        assert_eq!((ww[0].lo, ww[0].hi, ww[0].write), (0, 16, true));
        let rw = g.flow_windows(r.seq).unwrap();
        assert_eq!((rw[0].lo, rw[0].hi, rw[0].write), (16, 32, false));
        assert!(
            !ww[0].conflicts(&rw[0]),
            "disjoint halves the hull would have merged into a conflict"
        );
        assert!(g.flow_windows(999).is_none());
        // One whole-graph report per device, none with errors; the Warn
        // level reached every engine through the builder.
        let reports = g.verify_graph();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|(_, rep)| !rep.has_errors()));
        assert_eq!(reports[0].1.launches.len(), 2, "both launches sit on device 0");
        g.wait(w).unwrap();
        g.wait(r).unwrap();
        assert!(g
            .take_diagnostics()
            .iter()
            .all(|(_, d)| d.severity != crate::analysis::Severity::Error));
    }

    #[test]
    fn pinned_placement_and_auto_placement_by_occupancy() {
        let mut g = two_epiphanies();
        let a = g.alloc(MemSpec::host("a").zeroed(32)).unwrap();
        g.compile_kernel("total", SUM_SRC).unwrap();
        // Pinned on device 1.
        let h1 = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .on(DeviceId(1))
            .cores((0..4).collect())
            .submit()
            .unwrap();
        assert_eq!(h1.device(), DeviceId(1));
        // Automatic: device 1 has busy cores, device 0 is idle.
        let h2 = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .cores((0..4).collect())
            .submit()
            .unwrap();
        assert_eq!(h2.device(), DeviceId(0), "least-occupied device wins");
        h1.wait(&mut g).unwrap();
        h2.wait(&mut g).unwrap();
        assert_eq!(g.in_flight(), 0);
    }

    #[test]
    fn cross_device_read_after_write_stages_once_and_sees_values() {
        let mut g = two_epiphanies();
        let a = g.alloc(MemSpec::host("a").zeroed(32)).unwrap();
        g.compile_kernel("fill", FILL_SRC).unwrap();
        g.compile_kernel("total", SUM_SRC).unwrap();
        let w = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(a), GroupArgSpec::Float(1.0)])
            .on(DeviceId(0))
            .cores((0..4).collect())
            .submit()
            .unwrap();
        let r = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .on(DeviceId(1))
            .cores((0..4).collect())
            .submit()
            .unwrap();
        let rw = w.wait(&mut g).unwrap();
        let rr = r.wait(&mut g).unwrap();
        let sum: f64 = rr.reports.iter().map(|c| c.value.as_f64().unwrap()).sum();
        // fill writes (1 + i) per shard-local index i: 4 shards of 8.
        assert_eq!(sum, 4.0 * (8.0 + (0..8).sum::<i64>() as f64));
        let st = g.staging_counters();
        assert_eq!((st.copies, st.src_reads, st.dst_writes), (1, 1, 1));
        assert_eq!(st.bytes, 32 * 4);
        assert!(rr.launched_at >= rw.finished_at, "reader floored past the staged copy");
        // Re-running on the reader's device needs no second copy.
        let r2 = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .on(DeviceId(1))
            .cores((0..4).collect())
            .submit()
            .unwrap();
        r2.wait(&mut g).unwrap();
        assert_eq!(g.staging_counters().copies, 1, "replica is fresh now");
    }

    #[test]
    fn device_loss_migrates_budgeted_launch_to_survivor() {
        let mut g = GroupSession::builder()
            .device(Technology::epiphany3())
            .device(Technology::epiphany3())
            .seed(9)
            .faults(0, FaultPlan::new().lose_device(1))
            .build()
            .unwrap();
        let a = g.alloc(MemSpec::host("a").zeroed(32)).unwrap();
        g.compile_kernel("fill", FILL_SRC).unwrap();
        let h = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(a), GroupArgSpec::Float(1.0)])
            .on(DeviceId(0))
            .cores((0..4).collect())
            .retry(2)
            .submit()
            .unwrap();
        let r = h.wait(&mut g).unwrap();
        assert_eq!(r.reports.len(), 4);
        let fc = g.fault_counters();
        assert_eq!((fc.injected, fc.migrated, fc.abandoned), (1, 1, 0), "{fc:?}");
        // The migrated run lands exactly the fault-free values.
        let mut expect = vec![0.0f32; 32];
        for s in 0..4 {
            for i in 0..8 {
                expect[s * 8 + i] = 1.0 + i as f32;
            }
        }
        assert_eq!(g.read(a).unwrap(), expect);
        // New work avoids the lost device.
        let h2 = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(a), GroupArgSpec::Float(2.0)])
            .cores((0..4).collect())
            .submit()
            .unwrap();
        assert_eq!(h2.device(), DeviceId(1), "placement skips the lost device");
        h2.wait(&mut g).unwrap();
    }

    #[test]
    fn migration_without_capable_survivor_exhausts_to_dependency_failed() {
        let mut g = GroupSession::builder()
            .device(Technology::epiphany3())
            .seed(9)
            .faults(0, FaultPlan::new().lose_device(1))
            .build()
            .unwrap();
        let lost_name = g.tech(DeviceId(0)).name.to_string();
        let a = g.alloc(MemSpec::host("a").zeroed(32)).unwrap();
        g.compile_kernel("fill", FILL_SRC).unwrap();
        let h = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(a), GroupArgSpec::Float(1.0)])
            .cores((0..4).collect())
            .retry(3)
            .submit()
            .unwrap();
        match h.wait(&mut g).unwrap_err() {
            Error::DependencyFailed { dep_device: Some(d), .. } => assert_eq!(d, lost_name),
            other => panic!("expected DependencyFailed naming the lost device, got {other:?}"),
        }
        let fc = g.fault_counters();
        assert_eq!((fc.migrated, fc.abandoned), (0, 1), "{fc:?}");
        // Without budget the same loss is plain fail-fast: the engine's
        // CoreFault surfaces unchanged.
        let h2 = g
            .launch_named("fill")
            .unwrap()
            .args(&[GroupArgSpec::sharded_mut(a), GroupArgSpec::Float(1.0)])
            .cores((0..4).collect())
            .submit()
            .unwrap();
        assert!(h2.wait(&mut g).unwrap_err().is_transient());
    }

    #[test]
    fn cross_device_explicit_after_is_rejected() {
        let mut g = two_epiphanies();
        let a = g.alloc(MemSpec::host("a").zeroed(16)).unwrap();
        g.compile_kernel("total", SUM_SRC).unwrap();
        let h = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .on(DeviceId(0))
            .cores((0..2).collect())
            .submit()
            .unwrap();
        let err = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .on(DeviceId(1))
            .after(h)
            .submit()
            .unwrap_err()
            .to_string();
        assert!(err.contains("crosses devices"), "{err}");
        // Unpinned, the dependent follows its dependency's device instead
        // of the occupancy heuristic (which would otherwise pick the idle
        // device 1 and make the edge spuriously cross devices).
        let follower = g
            .launch_named("total")
            .unwrap()
            .arg(GroupArgSpec::sharded(a))
            .cores((4..8).collect())
            .after(h)
            .submit()
            .unwrap();
        assert_eq!(follower.device(), DeviceId(0));
        h.wait(&mut g).unwrap();
        follower.wait(&mut g).unwrap();
    }
}
