//! Argument marshalling: how host values become per-core kernel arguments.
//!
//! The offload call site describes each argument with an [`ArgSpec`];
//! marshalling resolves it per core into a [`BoundArg`]:
//!
//! * scalars are copied into the launch message (they are tiny);
//! * references are either **sharded** (each core receives a disjoint
//!   window of the variable — how the benchmark distributes image pixels)
//!   or **broadcast** (every core sees the whole view);
//! * under [`TransferMode::Eager`] reference arguments are materialised
//!   into core-local arrays at launch — unless they don't fit the
//!   scratchpad, in which case the engine *spills* them back to
//!   by-reference access (ePython's overflow-into-shared-memory
//!   behaviour, §2.2).

use crate::error::{Error, Result};
use crate::memory::DataRef;

use super::prefetch::PrefetchSpec;
use super::{Access, TransferMode};

/// Per-argument pre-fetch choice under [`TransferMode::Prefetch`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PrefetchChoice {
    /// Use the offload's `default_prefetch`.
    #[default]
    Default,
    /// Never pre-fetch this argument (plain by-reference access) — for
    /// arguments only touched by bulk tensor builtins, where a streaming
    /// buffer would waste on-core memory.
    Never,
    /// Use this specific annotation.
    Spec(PrefetchSpec),
}

/// One kernel argument as described at the offload call site.
#[derive(Debug, Clone)]
pub enum ArgSpec {
    /// A host scalar (float).
    Float(f64),
    /// A host scalar (int).
    Int(i64),
    /// A reference argument.
    Ref {
        /// The variable (full view or pre-sliced).
        dref: DataRef,
        /// Shard across the participating cores (`true`) or broadcast the
        /// whole view to every core (`false`).
        shard: bool,
        /// Read-only vs mutable (the access modifier).
        access: Access,
        /// Per-argument pre-fetch choice (§3.1's decorator argument).
        prefetch: PrefetchChoice,
    },
    /// A small host-side array copied by value into the launch message
    /// (e.g. the per-image hidden delta `dh` — hundreds of bytes). Always
    /// eager regardless of the transfer mode; must fit the core budget.
    Values(Vec<f64>),
    /// One distinct reference per core (e.g. per-core weight shards that
    /// are separate registry variables). `drefs.len()` must equal the
    /// participating core count.
    PerCore {
        /// Core-ordered references.
        drefs: Vec<DataRef>,
        /// Access modifier, applied to each.
        access: Access,
        /// Pre-fetch choice (as for `Ref`).
        prefetch: PrefetchChoice,
    },
}

impl ArgSpec {
    /// Convenience: a sharded read-only reference.
    pub fn sharded(dref: DataRef) -> ArgSpec {
        ArgSpec::Ref { dref, shard: true, access: Access::ReadOnly, prefetch: PrefetchChoice::Default }
    }

    /// Convenience: a broadcast read-only reference.
    pub fn broadcast(dref: DataRef) -> ArgSpec {
        ArgSpec::Ref { dref, shard: false, access: Access::ReadOnly, prefetch: PrefetchChoice::Default }
    }

    /// Convenience: a sharded mutable reference.
    pub fn sharded_mut(dref: DataRef) -> ArgSpec {
        ArgSpec::Ref { dref, shard: true, access: Access::Mutable, prefetch: PrefetchChoice::Default }
    }

    /// Attach a pre-fetch annotation.
    pub fn with_prefetch(self, spec: PrefetchSpec) -> ArgSpec {
        match self {
            ArgSpec::Ref { dref, shard, access, .. } => {
                ArgSpec::Ref { dref, shard, access, prefetch: PrefetchChoice::Spec(spec) }
            }
            ArgSpec::PerCore { drefs, access, .. } => {
                ArgSpec::PerCore { drefs, access, prefetch: PrefetchChoice::Spec(spec) }
            }
            other => other,
        }
    }

    /// Opt out of pre-fetching (bulk-tensor-only arguments).
    pub fn never_prefetch(self) -> ArgSpec {
        match self {
            ArgSpec::Ref { dref, shard, access, .. } => {
                ArgSpec::Ref { dref, shard, access, prefetch: PrefetchChoice::Never }
            }
            ArgSpec::PerCore { drefs, access, .. } => {
                ArgSpec::PerCore { drefs, access, prefetch: PrefetchChoice::Never }
            }
            other => other,
        }
    }
}

/// One argument resolved for one core.
#[derive(Debug, Clone)]
pub enum BoundArg {
    /// Scalar (in the launch message).
    Float(f64),
    /// Scalar int.
    Int(i64),
    /// Small by-value array (in the launch message).
    Values(Vec<f64>),
    /// Copy the window's data into core-local memory at launch.
    EagerCopy {
        /// This core's window.
        dref: DataRef,
        /// Mutable eager args are copied back at kernel completion.
        access: Access,
    },
    /// Pass the reference; the core fetches on demand / via pre-fetch.
    External {
        /// This core's window.
        dref: DataRef,
        /// Access modifier.
        access: Access,
        /// Pre-fetch annotation (None = pure on-demand).
        prefetch: Option<PrefetchSpec>,
    },
}

impl BoundArg {
    /// The argument's contribution to the launch's *data-flow set*: the
    /// registry window it touches and whether it may write there. Scalars
    /// and by-value arrays travel in the launch message and touch no
    /// registry storage. Eager copies read their window at activation (and
    /// mutable ones write it back at completion), so they flow exactly
    /// like reference arguments. The engine infers launch-graph dependency
    /// edges from these sets (`coordinator/engine.rs`).
    pub fn flow(&self) -> Option<(DataRef, Access)> {
        match self {
            BoundArg::Float(_) | BoundArg::Int(_) | BoundArg::Values(_) => None,
            BoundArg::EagerCopy { dref, access } | BoundArg::External { dref, access, .. } => {
                Some((*dref, *access))
            }
        }
    }
}

/// Resolve call-site arg specs into per-core bound arguments.
///
/// `cores` lists the participating physical core ids; sharded refs are
/// split into `cores.len()` near-equal windows in id order.
pub fn bind(
    args: &[ArgSpec],
    cores: &[usize],
    mode: TransferMode,
    default_prefetch: Option<PrefetchSpec>,
) -> Result<Vec<Vec<BoundArg>>> {
    if cores.is_empty() {
        return Err(Error::Coordinator("offload requires at least one core".into()));
    }
    let n = cores.len();
    let mut per_core: Vec<Vec<BoundArg>> = vec![Vec::with_capacity(args.len()); n];
    for spec in args {
        match spec {
            ArgSpec::Float(v) => per_core.iter_mut().for_each(|c| c.push(BoundArg::Float(*v))),
            ArgSpec::Int(v) => per_core.iter_mut().for_each(|c| c.push(BoundArg::Int(*v))),
            ArgSpec::Values(vals) => {
                per_core.iter_mut().for_each(|c| c.push(BoundArg::Values(vals.clone())))
            }
            ArgSpec::Ref { dref, shard, access, prefetch } => {
                let windows: Vec<DataRef> =
                    if *shard { dref.shards(n) } else { vec![*dref; n] };
                bind_windows(&mut per_core, windows, mode, *access, *prefetch, default_prefetch)?;
            }
            ArgSpec::PerCore { drefs, access, prefetch } => {
                if drefs.len() != n {
                    return Err(Error::Coordinator(format!(
                        "PerCore argument has {} refs for {n} cores",
                        drefs.len()
                    )));
                }
                bind_windows(
                    &mut per_core,
                    drefs.clone(),
                    mode,
                    *access,
                    *prefetch,
                    default_prefetch,
                )?;
            }
        }
    }
    Ok(per_core)
}

fn bind_windows(
    per_core: &mut [Vec<BoundArg>],
    windows: Vec<DataRef>,
    mode: TransferMode,
    access: Access,
    prefetch: PrefetchChoice,
    default_prefetch: Option<PrefetchSpec>,
) -> Result<()> {
    for (ci, win) in windows.into_iter().enumerate() {
        let bound = match (mode, prefetch) {
            (TransferMode::Eager, _) => BoundArg::EagerCopy { dref: win, access },
            (TransferMode::OnDemand, _) | (TransferMode::Prefetch, PrefetchChoice::Never) => {
                BoundArg::External { dref: win, access, prefetch: None }
            }
            (TransferMode::Prefetch, choice) => {
                let pf = match choice {
                    PrefetchChoice::Spec(s) => Some(s),
                    PrefetchChoice::Default => default_prefetch,
                    PrefetchChoice::Never => unreachable!(),
                }
                .ok_or_else(|| {
                    Error::Coordinator(
                        "prefetch mode requires a prefetch annotation \
                         (per-arg or offload default)"
                            .into(),
                    )
                })?;
                pf.validate()?;
                BoundArg::External { dref: win, access, prefetch: Some(pf) }
            }
        };
        per_core[ci].push(bound);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dref(len: usize) -> DataRef {
        DataRef { id: 9, offset: 0, len }
    }

    fn pf() -> PrefetchSpec {
        PrefetchSpec {
            buffer_size: 16,
            elems_per_fetch: 8,
            distance: 8,
            access: Access::ReadOnly,
        }
    }

    #[test]
    fn sharding_splits_disjoint_windows() {
        let bound =
            bind(&[ArgSpec::sharded(dref(100))], &[0, 1, 2, 3], TransferMode::OnDemand, None)
                .unwrap();
        assert_eq!(bound.len(), 4);
        let mut covered = 0;
        for c in &bound {
            let BoundArg::External { dref, .. } = &c[0] else { panic!() };
            assert_eq!(dref.offset, covered);
            covered += dref.len;
        }
        assert_eq!(covered, 100);
    }

    #[test]
    fn broadcast_gives_every_core_full_view() {
        let bound =
            bind(&[ArgSpec::broadcast(dref(50))], &[0, 1], TransferMode::OnDemand, None).unwrap();
        for c in &bound {
            let BoundArg::External { dref, .. } = &c[0] else { panic!() };
            assert_eq!((dref.offset, dref.len), (0, 50));
        }
    }

    #[test]
    fn eager_mode_produces_copies() {
        let bound =
            bind(&[ArgSpec::sharded(dref(10))], &[0], TransferMode::Eager, None).unwrap();
        assert!(matches!(bound[0][0], BoundArg::EagerCopy { .. }));
    }

    #[test]
    fn prefetch_mode_requires_annotation() {
        let err = bind(&[ArgSpec::sharded(dref(10))], &[0], TransferMode::Prefetch, None);
        assert!(err.is_err());
        let ok = bind(&[ArgSpec::sharded(dref(10))], &[0], TransferMode::Prefetch, Some(pf()))
            .unwrap();
        let BoundArg::External { prefetch, .. } = &ok[0][0] else { panic!() };
        assert!(prefetch.is_some());
    }

    #[test]
    fn per_arg_prefetch_overrides_default() {
        let custom = PrefetchSpec { buffer_size: 99, ..pf() };
        let bound = bind(
            &[ArgSpec::sharded(dref(10)).with_prefetch(custom)],
            &[0],
            TransferMode::Prefetch,
            Some(pf()),
        )
        .unwrap();
        let BoundArg::External { prefetch: Some(p), .. } = &bound[0][0] else { panic!() };
        assert_eq!(p.buffer_size, 99);
    }

    #[test]
    fn scalars_replicate() {
        let bound = bind(
            &[ArgSpec::Float(1.5), ArgSpec::Int(7)],
            &[0, 1, 2],
            TransferMode::OnDemand,
            None,
        )
        .unwrap();
        for c in &bound {
            assert!(matches!(c[0], BoundArg::Float(v) if v == 1.5));
            assert!(matches!(c[1], BoundArg::Int(7)));
        }
    }

    #[test]
    fn zero_cores_rejected() {
        assert!(bind(&[], &[], TransferMode::Eager, None).is_err());
    }

    #[test]
    fn per_core_refs_bind_one_each() {
        let refs: Vec<DataRef> =
            (0..3).map(|i| DataRef { id: 10 + i, offset: 0, len: 8 }).collect();
        let bound = bind(
            &[ArgSpec::PerCore { drefs: refs, access: Access::Mutable, prefetch: PrefetchChoice::Default }],
            &[0, 1, 2],
            TransferMode::OnDemand,
            None,
        )
        .unwrap();
        for (ci, c) in bound.iter().enumerate() {
            let BoundArg::External { dref, access, .. } = &c[0] else { panic!() };
            assert_eq!(dref.id, 10 + ci as u64);
            assert_eq!(*access, Access::Mutable);
        }
        // count mismatch rejected
        let refs: Vec<DataRef> = (0..2).map(|i| DataRef { id: i, offset: 0, len: 8 }).collect();
        assert!(bind(
            &[ArgSpec::PerCore { drefs: refs, access: Access::ReadOnly, prefetch: PrefetchChoice::Default }],
            &[0, 1, 2],
            TransferMode::OnDemand,
            None,
        )
        .is_err());
    }

    #[test]
    fn values_arg_replicates_by_value() {
        let bound = bind(
            &[ArgSpec::Values(vec![1.0, 2.0])],
            &[0, 1],
            TransferMode::OnDemand,
            None,
        )
        .unwrap();
        for c in &bound {
            assert!(matches!(&c[0], BoundArg::Values(v) if v == &vec![1.0, 2.0]));
        }
    }
}
