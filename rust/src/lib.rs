//! # microcore — hierarchical-memory offload abstractions for micro-core architectures
//!
//! A production-quality reproduction of *Jamieson & Brown, "High level
//! programming abstractions for leveraging hierarchical memories with
//! micro-core architectures"* (JPDC 2020, DOI 10.1016/j.jpdc.2019.11.011).
//!
//! Micro-core architectures (Epiphany-III, multi-core MicroBlaze soft-cores)
//! pack many simple cores with *kilobytes* of manually-managed local memory.
//! Offloading kernels to them cannot assume the accelerator can hold its
//! arguments: the paper's contribution is a **pass-by-reference** kernel
//! invocation model plus **pre-fetching** and **memory kinds**, letting
//! kernels process arbitrarily large data living anywhere in a deep memory
//! hierarchy — including levels the device cannot address directly.
//!
//! This crate implements the full system:
//!
//! * [`device`] — simulated micro-core hardware: technology presets
//!   (Epiphany-III, MicroBlaze ± FPU, Cortex-A9, …), clocks, scratchpads,
//!   off-chip links with contention, and an activity-based power model.
//! * [`memory`] — the memory hierarchy: [`memory::MemKind`] allocation
//!   classes (`Host`, `Shared`, `Microcore`, …), opaque [`memory::DataRef`]
//!   references that are what actually travels to the device, and the
//!   shared-window segment cache ([`memory::SharedCacheKind`]) that turns
//!   repeated passes over off-chip data into window-cost hits.
//! * [`channel`] — the paper's Fig. 2 communication substrate: per-core
//!   channels of thirty-two 1 KB cells in shared memory.
//! * [`vm`] — an ePython-like on-core interpreter (lexer → parser →
//!   bytecode → VM) whose symbol table carries the paper's `external` flag;
//!   external reads/writes become blocking or pre-fetched channel traffic.
//! * [`coordinator`] — the host-side offload engine: kernel registry,
//!   the asynchronous launch graph (`launch`/`submit`/`wait`/`poll`;
//!   dependency edges inferred from each launch's argument read/write
//!   sets plus explicit `.after` edges, with per-core occupancy — so a
//!   dependent chain needs no waits while non-conflicting launches
//!   pipeline on the shared virtual timeline), argument marshalling
//!   (eager copy vs by-reference), the pre-fetch engine, request
//!   servicing, device-resident data management, the sharded offload
//!   planner ([`coordinator::ShardPlan`]: block / block-cyclic
//!   decomposition with write-back merge, plus device-proportional
//!   splits), and multi-device plans ([`coordinator::GroupSession`]: one
//!   engine per technology, `.on(device)` placement, cross-device
//!   host-level staging — one launch graph spanning an Epiphany and a
//!   MicroBlaze at once).
//! * [`fleet`] — the serving layer above single sessions: a bounded pool
//!   of device groups multiplexing N independent tenants' seeded
//!   open-loop request streams, with bounded fair admission
//!   ([`Error::Overloaded`] load shedding), tenant-tagged launches and a
//!   deterministic latency/utilization report (per-class p50/p95/p99,
//!   Jain fairness, per-device busy fractions).
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) that carry the numeric hot path.
//! * [`workloads`] — the paper's benchmarks: the lung-scan neural-network
//!   training benchmark (Figs. 3–4), LINPACK (Table 1) and the synthetic
//!   stall-time probe (Table 2).
//! * [`analysis`] — the static launch verifier: an abstract interpreter
//!   over the kernel bytecode infers per-argument read/write windows,
//!   powering under-declared-flow and `.independent()`-conflict lints at
//!   submit ([`coordinator::SessionBuilder`]`::verify`), per-technology
//!   code/scratch budget checks at kernel registration, the whole-graph
//!   pre-flight `Session::verify_graph()`, and `microcore analyze`.
//!
//! ## Quick start
//!
//! ```no_run
//! use microcore::coordinator::{ArgSpec, Session, TransferMode};
//! use microcore::device::Technology;
//! use microcore::memory::MemSpec;
//!
//! let mut sess = Session::builder(Technology::epiphany3()).build().unwrap();
//! // One allocation entry point; the MemSpec constructor picks the level.
//! let a = sess.alloc(MemSpec::host("a").from(&vec![1.0; 1000])).unwrap();
//! let b = sess.alloc(MemSpec::host("b").from(&vec![2.0; 1000])).unwrap();
//! let kernel = sess
//!     .compile_kernel(
//!         "sum",
//!         "def mykernel(a, b):\n    ret = [0.0] * len(a)\n    i = 0\n    \
//!          while i < len(a):\n        ret[i] = a[i] + b[i]\n        i += 1\n    \
//!          return ret\n",
//!     )
//!     .unwrap();
//! // Launches are asynchronous: submit returns a handle, wait drives the
//! // virtual timeline. Dependent launches are ordered by inferred
//! // data-flow edges (no waits needed); non-conflicting ones pipeline.
//! let handle = sess
//!     .launch(&kernel)
//!     .args(&[ArgSpec::sharded(a), ArgSpec::sharded(b)])
//!     .mode(TransferMode::OnDemand)
//!     .submit()
//!     .unwrap();
//! let out = handle.wait(&mut sess).unwrap();
//! println!("elapsed {} virtual ns across {} cores", out.elapsed(), out.reports.len());
//! ```
//!
//! Determinism: the whole stack is a single-threaded discrete-event
//! simulation over virtual time (host service threads and link contention
//! are *modelled* resources), so every run with the same seed reproduces the
//! same timings bit-for-bit. The `xla` crate's PJRT client is `Rc`-based
//! (non-`Send`), which this design accommodates naturally.
//!
//! A module-by-module walkthrough mapping paper sections to source files —
//! including the request lifecycle and the fast-path/fusion invariants —
//! lives in `ARCHITECTURE.md` at the repository root.

// Every public item in this crate is documentation-bearing; CI builds the
// docs with `-D warnings`, so doc rot (or an undocumented addition) fails
// the build rather than silently accruing.
#![warn(missing_docs)]

pub mod analysis;
pub mod bench_support;
pub mod channel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod fleet;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod vm;
pub mod workloads;

pub use error::{Error, Result};
