//! One launch graph spanning two micro-core technologies.
//!
//! A [`DeviceGroup`] attaches an Epiphany-III *and* a MicroBlaze behind
//! one session surface. The walkthrough shows the three multi-device
//! mechanisms:
//!
//! 1. **Placement** — `.on(device)` pins a launch; omitting it places
//!    automatically on the least-occupied device.
//! 2. **Cross-device data flow** — a producer on the Epiphany fills a
//!    buffer a consumer on the MicroBlaze reduces; the group quiesces
//!    the producer, stages the buffer host-level (one host read + one
//!    host write, audited by `StagingCounters`) and floors the consumer
//!    past the copy. No device ever reads another device's local window
//!    directly — everything crosses at Host level or above.
//! 3. **Device-proportional sharding** — `ShardPlan::across_devices`
//!    splits a dataset 2:1 between the 16-core Epiphany and the 8-core
//!    MicroBlaze, and both slices reduce concurrently, each on its own
//!    device.
//!
//! ```text
//! cargo run --release --example hetero_pipeline [-- --n 4800]
//! ```

use microcore::cli::Cli;
use microcore::coordinator::{DeviceId, GroupArgSpec, GroupSession, ShardPlan, ShardPolicy};
use microcore::device::Technology;
use microcore::memory::MemSpec;
use microcore::metrics::report::{ms, staging_table, Table};

const FILL: &str = r#"
def fill(a, v):
    i = 0
    while i < len(a):
        a[i] = v
        i += 1
    return 0
"#;

const TOTAL: &str = r#"
def total(xs):
    s = 0.0
    i = 0
    while i < len(xs):
        s += xs[i]
        i += 1
    return s
"#;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("hetero_pipeline", "one launch graph spanning two technologies")
        .opt("n", Some("4800"), "elements in the shared buffer");
    let Some(args) = cli.parse(std::env::args().skip(1))? else {
        println!("{}", cli.help());
        return Ok(());
    };
    let n: usize = args.parse_as("n")?;

    let epi = Technology::epiphany3();
    let mb = Technology::microblaze_fpu();
    let mut group = GroupSession::builder().device(epi.clone()).device(mb.clone()).seed(42).build()?;
    let a = group.alloc(MemSpec::host("a").zeroed(n))?;
    group.compile_kernel("fill", FILL)?;
    group.compile_kernel("total", TOTAL)?;

    // ---- producer on the Epiphany, consumer on the MicroBlaze ----
    let producer = group
        .launch_named("fill")?
        .args(&[GroupArgSpec::sharded_mut(a), GroupArgSpec::Float(2.0)])
        .on(DeviceId(0))
        .submit()?;
    // Submitting the consumer quiesces the producer and stages the buffer
    // across the host level — the cross-device RAW edge.
    let consumer = group
        .launch_named("total")?
        .arg(GroupArgSpec::sharded(a))
        .on(DeviceId(1))
        .submit()?;
    let rp = producer.wait(&mut group)?;
    let rc = consumer.wait(&mut group)?;
    let sum: f64 = rc.reports.iter().map(|r| r.value.as_f64().unwrap()).sum();
    assert_eq!(sum, 2.0 * n as f64);

    let mut t = Table::new(
        format!("producer ({}) → consumer ({}) over {n} elements", epi.name, mb.name),
        &["stage", "finish (virtual ms)"],
    );
    t.row(&[format!("fill on {}", epi.name), ms(rp.finished_at)]);
    t.row(&[format!("total on {} (after staging)", mb.name), ms(rc.finished_at)]);
    print!("{}", t.render());
    print!("{}", staging_table("cross-device staging", &group.staging_counters()).render());
    assert!(rc.launched_at > rp.finished_at, "consumer floored past the staged copy");

    // ---- device-proportional sharding: 16 + 8 cores → 2:1 split ----
    // (The split geometry only needs a view; any device's replica works.)
    let base = group.device_ref(a, DeviceId(0))?;
    let slices = ShardPlan::device_split(base, &[epi.cores, mb.cores])?;
    println!(
        "\ndevice split over {} + {} cores: {} / {} elements",
        epi.cores, mb.cores, slices[0].len, slices[1].len
    );
    let plans = ShardPlan::across_devices(base, &[epi.cores, mb.cores], ShardPolicy::Block)?;
    // Each device reduces its own slice concurrently; automatic placement
    // spreads the two launches because each occupies one device fully.
    let ha = group
        .launch_named("total")?
        .arg(GroupArgSpec::sharded(a.slice(0, slices[0].len)))
        .on(DeviceId(0))
        .submit()?;
    let hb = group
        .launch_named("total")?
        .arg(GroupArgSpec::sharded(a.slice(slices[0].len, slices[1].len)))
        .on(DeviceId(1))
        .submit()?;
    let ra = ha.wait(&mut group)?;
    let rb = hb.wait(&mut group)?;
    let sa: f64 = ra.reports.iter().map(|r| r.value.as_f64().unwrap()).sum();
    let sb: f64 = rb.reports.iter().map(|r| r.value.as_f64().unwrap()).sum();
    assert_eq!(sa + sb, 2.0 * n as f64, "the split covers the buffer exactly once");
    println!(
        "proportional reduce: {} cores took {:.0}, {} cores took {:.0} (plans: {} + {})",
        epi.cores,
        sa / 2.0,
        mb.cores,
        sb / 2.0,
        plans[0].cores(),
        plans[1].cores(),
    );
    println!("\nOne graph, two technologies — the host hierarchy is the bridge.");
    Ok(())
}
