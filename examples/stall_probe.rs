//! Table 2 interactive driver: the synthetic stall-time probe.
//!
//! ```text
//! cargo run --release --example stall_probe [-- --tech microblaze+fpu]
//! ```

use microcore::cli::Cli;
use microcore::device::Technology;
use microcore::metrics::report::{f3, Table};
use microcore::workloads::stall;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("stall_probe", "Table 2: single-transfer stall times")
        .opt("tech", Some("epiphany"), "technology preset")
        .opt("trials", Some("500"), "trials per configuration")
        .opt("seed", Some("7"), "seed");
    let Some(args) = cli.parse(std::env::args().skip(1))? else {
        println!("{}", cli.help());
        return Ok(());
    };
    let tech = Technology::by_name(args.req("tech")?)
        .ok_or_else(|| anyhow::anyhow!("unknown technology"))?;
    let trials: usize = args.parse_as("trials")?;
    let rows = stall::stall_table(&tech, trials, args.parse_as("seed")?);

    let mut t = Table::new(
        format!("Table 2 — micro-core stall time, {} ({} trials)", tech.name, trials),
        &["size", "mode", "min (ms)", "max (ms)", "mean (ms)"],
    );
    for r in &rows {
        t.row(&[
            format!("{}B", r.size),
            r.mode.to_string(),
            f3(r.min_ms),
            f3(r.max_ms),
            f3(r.mean_ms),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nPaper (Epiphany): 128B 0.099/0.112/0.104 | 1KB 0.759/0.955/0.816 | \
         8KB 6.396/11.801/7.882 (on-demand min/max/mean, ms)\n\
         Key shape: at 8KB pre-fetch's mean exceeds on-demand's (polling tax)\n\
         while its max is lower (pre-posted requests dodge scheduling spikes)."
    );
    Ok(())
}
