//! Memory-kinds tour: §3.2 in action.
//!
//! The same reduction kernel runs over data allocated in every level of
//! the hierarchy — `Host` (not device addressable on the Epiphany),
//! `Shared` (the 32 MB window), `Microcore` (per-core local store), and
//! the extensibility demo `File` kind (backing store on disk) — with only
//! the *allocation call* changing, exactly the paper's one-line-change
//! claim. The table shows how transfer cost follows the kind.
//!
//! Also demonstrated: the eager-copy spill (Listing 1's failure mode) and
//! the device-resident data API (`define_on_device` / `copy_to_device` /
//! `copy_from_device`).
//!
//! ```text
//! cargo run --release --example memory_kinds
//! ```

use microcore::coordinator::{ArgSpec, OffloadOptions, Session, TransferMode};
use microcore::device::Technology;
use microcore::memory::DataRef;
use microcore::metrics::report::{ms, Table};

const SUM_KERNEL: &str = r#"
def total(xs):
    s = 0.0
    i = 0
    while i < len(xs):
        s += xs[i]
        i += 1
    return s
"#;

fn main() -> anyhow::Result<()> {
    let tech = Technology::epiphany3();
    let n = 1600usize; // 100 elements per core
    let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let expect: f64 = data.iter().map(|&v| f64::from(v)).sum();

    let mut table = Table::new(
        "One kernel, four memory kinds (on-demand access)",
        &["kind", "level", "elapsed (virtual ms)", "sum"],
    );

    let tmp = std::env::temp_dir().join(format!("mk_kinds_{}.f32", std::process::id()));
    for kind in ["host", "shared", "microcore", "file"] {
        let mut sess = Session::builder(tech.clone()).seed(1).build()?;
        // THE one-line change of §3.2:
        let dref: DataRef = match kind {
            "host" => sess.alloc_host_f32("xs", &data)?,
            "shared" => sess.alloc_shared_f32("xs", &data)?,
            "microcore" => {
                // Per-core replicas hold per-core shards here: allocate a
                // shard-sized replica and fill each core's copy.
                let shard = n / tech.cores;
                let d = sess.define_on_device("xs", shard)?;
                for c in 0..tech.cores {
                    sess.engine_mut().registry_mut().write(
                        d,
                        Some(c),
                        0,
                        &data[c * shard..(c + 1) * shard],
                    )?;
                }
                d
            }
            _ => {
                let d = sess.alloc_file_f32("xs", &tmp, n)?;
                sess.write(d, 0, &data)?;
                d
            }
        };
        let kernel = sess.compile_kernel("total", SUM_KERNEL)?;
        // Microcore replicas are per-core shards (broadcast view); others
        // are sharded host-side variables.
        let arg = if kind == "microcore" {
            ArgSpec::broadcast(dref)
        } else {
            ArgSpec::sharded(dref)
        };
        let res = sess.offload(
            &kernel,
            &[arg],
            OffloadOptions::default().transfer(TransferMode::OnDemand),
        )?;
        let total: f64 = res.reports.iter().map(|r| r.value.as_f64().unwrap()).sum();
        assert!((total - expect).abs() < 1e-3, "{kind}: {total} vs {expect}");
        let info = sess.engine().registry().info(dref)?;
        table.row(&[
            kind.to_string(),
            info.level.name().to_string(),
            ms(res.elapsed()),
            format!("{total:.0}"),
        ]);
    }
    std::fs::remove_file(&tmp).ok();
    print!("{}", table.render());

    // --- Listing 1's failure mode: eager copy that cannot fit ---------
    let mut sess = Session::builder(tech.clone()).seed(1).build()?;
    let big = sess.alloc_host_zeroed("big", 4000 * 16)?; // 16 KB/core
    let kernel = sess.compile_kernel("total", SUM_KERNEL)?;
    let res = sess.offload(
        &kernel,
        &[ArgSpec::sharded(big)],
        OffloadOptions::default().transfer(TransferMode::Eager),
    )?;
    println!(
        "\nEager copy of 16 KB/core into a ~7 KB scratchpad: {} argument(s) \
         spilled to\nby-reference access (ePython's overflow behaviour) — the \
         kernel still ran.",
        res.spills
    );

    // --- Device-resident data API (§2.2) ------------------------------
    let mut sess = Session::builder(tech).seed(1).build()?;
    let counter = sess.define_on_device("counter", 1)?;
    sess.copy_to_device(counter, &[100.0])?;
    let bump = sess.compile_kernel(
        "bump",
        "def bump(c):\n    c[0] = c[0] + 1.0 + core_id()\n    return c[0]\n",
    )?;
    sess.offload(
        &bump,
        &[ArgSpec::Ref {
            dref: counter,
            shard: false,
            access: microcore::coordinator::Access::Mutable,
            prefetch: microcore::coordinator::PrefetchChoice::Default,
        }],
        OffloadOptions::default().transfer(TransferMode::OnDemand),
    )?;
    println!(
        "\ndefine_on_device/copy_to_device/copy_from_device: core 0 counter = {}, \
         core 15 counter = {}",
        sess.copy_from_device(counter, 0)?[0],
        sess.copy_from_device(counter, 15)?[0],
    );
    Ok(())
}
