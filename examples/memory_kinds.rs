//! Memory-kinds tour: §3.2 in action.
//!
//! The same reduction kernel runs over data allocated in every level of
//! the hierarchy — `Host` (not device addressable on the Epiphany),
//! `Shared` (the 32 MB window), `Microcore` (per-core local store), and
//! the extensibility demo `File` kind (backing store on disk) — with only
//! the *allocation call* changing, exactly the paper's one-line-change
//! claim. The table shows how transfer cost follows the kind.
//!
//! Also demonstrated: the eager-copy spill (Listing 1's failure mode) and
//! the device-resident data API (`define_on_device` / `copy_to_device` /
//! `copy_from_device`).
//!
//! ```text
//! cargo run --release --example memory_kinds
//! ```

use microcore::coordinator::{ArgSpec, Session, TransferMode};
use microcore::device::Technology;
use microcore::memory::{DataRef, MemSpec};
use microcore::metrics::report::{ms, Table};

const SUM_KERNEL: &str = r#"
def total(xs):
    s = 0.0
    i = 0
    while i < len(xs):
        s += xs[i]
        i += 1
    return s
"#;

fn main() -> anyhow::Result<()> {
    let tech = Technology::epiphany3();
    let n = 1600usize; // 100 elements per core
    let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let expect: f64 = data.iter().map(|&v| f64::from(v)).sum();

    let mut table = Table::new(
        "One kernel, four memory kinds (on-demand access)",
        &["kind", "level", "elapsed (virtual ms)", "sum"],
    );

    let tmp = std::env::temp_dir().join(format!("mk_kinds_{}.f32", std::process::id()));
    for kind in ["host", "shared", "microcore", "file"] {
        let mut sess = Session::builder(tech.clone()).seed(1).build()?;
        // THE one-line change of §3.2 — swap the MemSpec constructor:
        let dref: DataRef = match kind {
            "host" => sess.alloc(MemSpec::host("xs").from(&data))?,
            "shared" => sess.alloc(MemSpec::shared("xs").from(&data))?,
            "microcore" => {
                // Per-core replicas hold per-core shards here: allocate a
                // shard-sized replica and fill each core's copy.
                let shard = n / tech.cores;
                let d = sess.define_on_device("xs", shard)?;
                for c in 0..tech.cores {
                    sess.engine_mut().registry_mut().write(
                        d,
                        Some(c),
                        0,
                        &data[c * shard..(c + 1) * shard],
                    )?;
                }
                d
            }
            _ => sess.alloc(MemSpec::file("xs", &tmp).from(&data))?,
        };
        let kernel = sess.compile_kernel("total", SUM_KERNEL)?;
        // Microcore replicas are per-core shards (broadcast view); others
        // are sharded host-side variables.
        let arg = if kind == "microcore" {
            ArgSpec::broadcast(dref)
        } else {
            ArgSpec::sharded(dref)
        };
        let res = sess
            .launch(&kernel)
            .arg(arg)
            .mode(TransferMode::OnDemand)
            .submit()?
            .wait(&mut sess)?;
        let total: f64 = res.reports.iter().map(|r| r.value.as_f64().unwrap()).sum();
        assert!((total - expect).abs() < 1e-3, "{kind}: {total} vs {expect}");
        let info = sess.engine().registry().info(dref)?;
        table.row(&[
            kind.to_string(),
            info.level.name().to_string(),
            ms(res.elapsed()),
            format!("{total:.0}"),
        ]);
    }
    std::fs::remove_file(&tmp).ok();
    print!("{}", table.render());

    // --- Listing 1's failure mode: eager copy that cannot fit ---------
    let mut sess = Session::builder(tech.clone()).seed(1).build()?;
    let big = sess.alloc(MemSpec::host("big").zeroed(4000 * 16))?; // 16 KB/core
    let kernel = sess.compile_kernel("total", SUM_KERNEL)?;
    let res = sess
        .launch(&kernel)
        .arg(ArgSpec::sharded(big))
        .mode(TransferMode::Eager)
        .submit()?
        .wait(&mut sess)?;
    println!(
        "\nEager copy of 16 KB/core into a ~7 KB scratchpad: {} argument(s) \
         spilled to\nby-reference access (ePython's overflow behaviour) — the \
         kernel still ran.",
        res.spills
    );

    // --- Device-resident data API (§2.2) ------------------------------
    let mut sess = Session::builder(tech).seed(1).build()?;
    let counter = sess.define_on_device("counter", 1)?;
    sess.copy_to_device(counter, &[100.0])?;
    let bump = sess.compile_kernel(
        "bump",
        "def bump(c):\n    c[0] = c[0] + 1.0 + core_id()\n    return c[0]\n",
    )?;
    sess.launch(&bump)
        .arg(ArgSpec::Ref {
            dref: counter,
            shard: false,
            access: microcore::coordinator::Access::Mutable,
            prefetch: microcore::coordinator::PrefetchChoice::Default,
        })
        .mode(TransferMode::OnDemand)
        .submit()?
        .wait(&mut sess)?;
    println!(
        "\ndefine_on_device/copy_to_device/copy_from_device: core 0 counter = {}, \
         core 15 counter = {}",
        sess.copy_from_device(counter, 0)?[0],
        sess.copy_from_device(counter, 15)?[0],
    );
    Ok(())
}
