//! Quickstart: the paper's Listings 1–3, end to end.
//!
//! Two 1000-element lists are summed on the micro-cores three ways:
//!
//! 1. **eager** (Listing 1, legacy behaviour) — whole arguments copied to
//!    each core at launch;
//! 2. **on-demand** (the §3.1 pass-by-reference model) — a reference is
//!    sent; every element access is a host-serviced round trip;
//! 3. **pre-fetch** (Listing 2) — same reference, with
//!    `prefetch={a, 10, 2, 10, read_only}`-style annotations streaming
//!    chunks ahead of use.
//!
//! Memory kinds (Listing 3) pick where `nums1`/`nums2` live: run with
//! `--kind shared` to move them into the device-addressable window and
//! watch the transfer cost change — a one-line change, as §3.2 promises.
//!
//! ```text
//! cargo run --release --example quickstart [-- --kind host|shared --tech epiphany]
//! ```

use microcore::cli::Cli;
use microcore::coordinator::{Access, ArgSpec, PrefetchSpec, Session, TransferMode};
use microcore::device::Technology;
use microcore::memory::MemSpec;
use microcore::metrics::report::{ms, Table};
use microcore::sim::Rng;

const KERNEL: &str = r#"
def mykernel(a, b):
    ret_data = [0.0] * len(a)
    i = 0
    while i < len(a):
        ret_data[i] = a[i] + b[i]
        i += 1
    return ret_data
"#;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("quickstart", "paper Listings 1-3: offload a vector sum")
        .opt("tech", Some("epiphany"), "technology preset")
        .opt("kind", Some("host"), "memory kind for the inputs (host|shared)")
        .opt("n", Some("1000"), "elements per list");
    let Some(args) = cli.parse(std::env::args().skip(1))? else {
        println!("{}", cli.help());
        return Ok(());
    };
    let tech = Technology::by_name(args.req("tech")?)
        .ok_or_else(|| anyhow::anyhow!("unknown technology"))?;
    let n: usize = args.parse_as("n")?;
    let kind = args.req("kind")?.to_string();

    // Host-side data, exactly like the paper's `random.randrange` loop.
    let mut rng = Rng::new(7);
    let nums1: Vec<f32> = (0..n).map(|_| rng.range_u64(0, 100) as f32).collect();
    let nums2: Vec<f32> = (0..n).map(|_| rng.range_u64(0, 100) as f32).collect();

    let mut table = Table::new(
        format!("quickstart: {} cores, {n} elements, {kind} kind", tech.cores),
        &["mode", "elapsed (virtual ms)", "requests", "stall (ms)", "checksum"],
    );

    for mode in [TransferMode::Eager, TransferMode::OnDemand, TransferMode::Prefetch] {
        let mut sess = Session::builder(tech.clone()).seed(42).build()?;
        // Listing 3: the memory kind is one call-site choice — swap the
        // MemSpec constructor and everything downstream follows.
        let (a, b) = match kind.as_str() {
            "shared" => (
                sess.alloc(MemSpec::shared("nums1").from(&nums1))?,
                sess.alloc(MemSpec::shared("nums2").from(&nums2))?,
            ),
            _ => (
                sess.alloc(MemSpec::host("nums1").from(&nums1))?,
                sess.alloc(MemSpec::host("nums2").from(&nums2))?,
            ),
        };
        let kernel = sess.compile_kernel("mykernel", KERNEL)?;
        // The launch builder replaces the blocking offload call; submit
        // returns a handle, wait drives the virtual timeline.
        let builder =
            sess.launch(&kernel).args(&[ArgSpec::sharded(a), ArgSpec::sharded(b)]);
        // Listing 2's annotation: buffer 10 elements, fetch 2, distance 10.
        let handle = match mode {
            TransferMode::Prefetch => builder.prefetch(PrefetchSpec {
                buffer_size: 10,
                elems_per_fetch: 2,
                distance: 10,
                access: Access::ReadOnly,
            }),
            m => builder.mode(m),
        }
        .submit()?;
        let res = handle.wait(&mut sess)?;

        // Gather the per-core result lists (the paper's returned list of
        // per-core values) and checksum them.
        let mut checksum = 0.0f64;
        let mut count = 0usize;
        for r in &res.reports {
            let v = r.value.as_array()?.borrow().clone();
            count += v.len();
            checksum += v.iter().sum::<f64>();
        }
        assert_eq!(count, n, "every element summed exactly once");
        let expect: f64 = nums1.iter().zip(&nums2).map(|(x, y)| f64::from(x + y)).sum();
        assert!((checksum - expect).abs() < 1e-6, "numerics identical in every mode");

        table.row(&[
            mode.name().to_string(),
            ms(res.elapsed()),
            res.total_requests().to_string(),
            ms(res.total_stall()),
            format!("{checksum:.1}"),
        ]);
    }

    print!("{}", table.render());
    println!(
        "\nNote how the checksum is identical in every row — the transfer mode\n\
         changes *where the time goes*, never the result (§3.1)."
    );
    Ok(())
}
