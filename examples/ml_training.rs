//! End-to-end training driver — the full stack on a real workload.
//!
//! Trains the paper's one-hidden-layer (100 neuron) lesion classifier on
//! synthetic 3600-pixel CT scans for a few hundred steps, exercising every
//! layer of the system on the request path:
//!
//! ```text
//!   coordinator (offload, pass-by-reference, pre-fetch engine)
//!     → per-core channels (32 × 1 KB cells) → host service → link model
//!       → on-core VM (ePython-like interpreter, external flag)
//!         → tensor builtins → PJRT → AOT-compiled JAX/Pallas kernels
//! ```
//!
//! The loss curve is printed and written to `reports/ml_training_loss.csv`;
//! EXPERIMENTS.md records a reference run. Numerics are real: the loss
//! falls and held-out accuracy rises because the gradients computed by the
//! Pallas kernels are correct.
//!
//! ```text
//! make artifacts && cargo run --release --example ml_training
//! ```

use microcore::cli::Cli;
use microcore::coordinator::{Session, TransferMode};
use microcore::device::Technology;
use microcore::metrics::report::{ms, Table};
use microcore::sim::to_secs;
use microcore::workloads::mlbench::{MlBench, MlBenchConfig};

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("ml_training", "train the lesion classifier end-to-end")
        .opt("tech", Some("epiphany"), "technology preset")
        .opt("steps", Some("300"), "training images (steps)")
        .opt("mode", Some("prefetch"), "transfer mode")
        .opt("artifacts", Some("artifacts"), "AOT artifacts directory")
        .opt("seed", Some("42"), "seed");
    let Some(args) = cli.parse(std::env::args().skip(1))? else {
        println!("{}", cli.help());
        return Ok(());
    };
    let tech = Technology::by_name(args.req("tech")?)
        .ok_or_else(|| anyhow::anyhow!("unknown technology"))?;
    let steps: usize = args.parse_as("steps")?;
    let mode = TransferMode::parse(args.req("mode")?)
        .ok_or_else(|| anyhow::anyhow!("bad --mode"))?;

    let session = Session::builder(tech.clone())
        .artifacts_dir(args.req("artifacts")?)
        .seed(args.parse_as("seed")?)
        .build()?;

    let mut cfg = MlBenchConfig::small(tech.cores, mode);
    cfg.images = steps;
    let wall = std::time::Instant::now();
    let mut bench = MlBench::new(session, cfg)?;
    let result = bench.run()?;
    let wall = wall.elapsed();

    // Loss curve: print every 20th step and persist the full series.
    println!("step  loss      prediction  label");
    let mut csv = Table::new("ml_training loss curve", &["step", "loss", "prediction"]);
    for (i, (&loss, &yhat)) in result.losses.iter().zip(&result.predictions).enumerate() {
        csv.row(&[i.to_string(), format!("{loss:.6}"), format!("{yhat:.4}")]);
        if i % 20 == 0 || i + 1 == result.losses.len() {
            println!("{i:>4}  {loss:<8.4}  {yhat:<10.4}  {}", i % 2);
        }
    }
    if let Ok(path) = csv.save_csv("reports", "ml_training_loss") {
        println!("\nloss curve written to {}", path.display());
    }

    // Summary: did it learn?
    let k = (steps / 5).max(1);
    let first: f32 = result.losses[..k].iter().sum::<f32>() / k as f32;
    let last: f32 = result.losses[steps - k..].iter().sum::<f32>() / k as f32;
    // Held-out-style accuracy over the final fifth: prediction rounds to
    // the (alternating) label.
    let correct = result.predictions[steps - k..]
        .iter()
        .enumerate()
        .filter(|(i, &p)| {
            let label = ((steps - k + i) % 2) as f32;
            (p > 0.5) == (label > 0.5)
        })
        .count();

    let mut t = Table::new(
        format!("ml_training summary — {} / {}", tech.name, mode.name()),
        &["metric", "value"],
    );
    t.row(&["steps".into(), steps.to_string()]);
    t.row(&["mean loss (first fifth)".into(), format!("{first:.4}")]);
    t.row(&["mean loss (last fifth)".into(), format!("{last:.4}")]);
    t.row(&["accuracy (last fifth)".into(), format!("{}/{k}", correct)]);
    t.row(&["feed forward / image".into(), format!("{} ms", ms(result.per_image.feed_forward))]);
    t.row(&[
        "combine gradients / image".into(),
        format!("{} ms", ms(result.per_image.combine_gradients)),
    ]);
    t.row(&["model update / image".into(), format!("{} ms", ms(result.per_image.model_update))]);
    t.row(&["virtual device time".into(), format!("{:.3} s", to_secs(bench.session().now()))]);
    t.row(&["energy (modelled)".into(), format!("{:.3} J", bench.session().engine().energy())]);
    t.row(&["wallclock".into(), format!("{:.1} s", wall.as_secs_f64())]);
    t.row(&["pjrt executions".into(), match bench.session().engine().executor() {
        Some(ex) => ex.ctx().executions().to_string(),
        None => "0 (native fallback)".into(),
    }]);
    print!("{}", t.render());

    anyhow::ensure!(last < first * 0.7, "training failed to reduce the loss");
    anyhow::ensure!(correct * 10 >= k * 7, "accuracy below 70% on final fifth");
    println!("\nOK: loss fell {first:.3} → {last:.3}; the full stack composes.");
    Ok(())
}
