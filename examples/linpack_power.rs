//! Table 1 interactive driver: LINPACK performance and power efficiency.
//!
//! Runs the in-core LU benchmark on every technology preset, prints the
//! paper's table plus the comparison points §5.1 discusses (Pascal /
//! Maxwell GPUs, Jetson TX1, Cortex-A53, Haswell — literature values the
//! paper cites, reproduced here as fixed reference rows).
//!
//! ```text
//! cargo run --release --example linpack_power
//! ```

use microcore::metrics::report::{f3, Table};
use microcore::workloads::linpack;

fn main() -> anyhow::Result<()> {
    let rows = linpack::table1(linpack::DEFAULT_N, 42)?;
    let mut t = Table::new(
        "Table 1 — LINPACK performance and power consumption",
        &["Technology", "MFLOPs", "Watts", "GFLOPs/Watt", "residual"],
    );
    for r in &rows {
        t.row(&[
            r.technology.clone(),
            format!("{:.2}", r.mflops),
            format!("{:.2}", r.watts),
            f3(r.gflops_per_watt),
            format!("{:.1e}", r.residual),
        ]);
    }
    print!("{}", t.render());

    // §5.1's literature comparison points, for context.
    let mut c = Table::new(
        "Literature comparison (values cited by the paper, not simulated)",
        &["Technology", "GFLOPs", "Watts", "GFLOPs/Watt"],
    );
    for (name, gflops, watts, eff) in [
        ("Pascal GPU (ML workload)", f64::NAN, 250.0, 42.0),
        ("Maxwell GPU (ML workload)", f64::NAN, 250.0, 23.0),
        ("Jetson TX1 (Tegra X1)", 16.0, 15.3, 1.2),
        ("Cortex-A53 (quad)", 4.43, 5.1, 1.07),
        ("Haswell 16-core", 47.7, 29.1, 1.64),
        ("Zynq-7020 theoretical", 180.0, f64::NAN, 72.0),
    ] {
        c.row(&[
            name.to_string(),
            if gflops.is_nan() { "-".into() } else { format!("{gflops:.2}") },
            if watts.is_nan() { "-".into() } else { format!("{watts:.1}") },
            format!("{eff:.2}"),
        ]);
    }
    print!("\n{}", c.render());

    // The §5.1 headline ratios, checked.
    let eff = |name: &str| rows.iter().find(|r| r.technology == name).unwrap().gflops_per_watt;
    let e = eff("Epiphany-III");
    println!("\nEpiphany vs MicroBlaze+FPU efficiency: {:.1}x (paper: ~6x)", e / eff("MicroBlaze+FPU"));
    println!("Epiphany vs Cortex-A9 efficiency:      {:.1}x (paper: ~30x)", e / eff("Cortex-A9"));
    println!(
        "Epiphany vs MicroBlaze+FPU FLOP rate:  {:.1}x (paper: ~31x)",
        rows[0].mflops / rows[2].mflops
    );
    Ok(())
}
