//! Wait-free pipelined chains over the launch graph.
//!
//! A three-stage pipeline — `fill` produces a buffer, `scale` transforms
//! it into a second buffer, `total` reduces that — is run two ways over
//! the same data:
//!
//! 1. **blocking** — every launch is waited before the next is
//!    submitted (the classic coordinator-sequenced choreography);
//! 2. **wait-free** — all three launches are submitted back to back with
//!    **no** `wait()` between them. The engine infers the ordering from
//!    each launch's argument read/write set (`scale` reads what `fill`
//!    wrote, `total` reads what `scale` wrote), so the chain executes
//!    bit-identically to the blocking run — same results, same virtual
//!    times — while the caller's code has no scheduling logic left.
//!
//! A fourth, *independent* launch (different buffer, different cores) is
//! then submitted after the chain: with no data-flow conflict it
//! overlaps the chain instead of queueing behind it, which is the whole
//! point — the coordinator, not the kernel author, decides when data
//! moves and what may run concurrently.
//!
//! ```text
//! cargo run --release --example deps_pipeline [-- --n 4000]
//! ```

use microcore::cli::Cli;
use microcore::coordinator::{ArgSpec, LaunchStatus, Session, TransferMode};
use microcore::device::Technology;
use microcore::memory::MemSpec;
use microcore::metrics::report::{ms, Table};

const FILL: &str = r#"
def fill(a, v):
    i = 0
    while i < len(a):
        a[i] = v + i
        i += 1
    return 0
"#;

const SCALE: &str = r#"
def scale(a, b):
    i = 0
    while i < len(a):
        b[i] = a[i] * 2.0
        i += 1
    return 0
"#;

const TOTAL: &str = r#"
def total(xs):
    s = 0.0
    i = 0
    while i < len(xs):
        s += xs[i]
        i += 1
    return s
"#;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("deps_pipeline", "wait-free pipelined chains over the launch graph")
        .opt("n", Some("4000"), "elements per buffer");
    let Some(args) = cli.parse(std::env::args().skip(1))? else {
        println!("{}", cli.help());
        return Ok(());
    };
    // The walkthrough stages launches on fixed core quarters/halves, so it
    // pins the 16-core Epiphany-III preset.
    let tech = Technology::epiphany3();
    let n: usize = args.parse_as("n")?;

    let run = |wait_free: bool| -> anyhow::Result<(f64, u64, u64)> {
        let mut sess = Session::builder(tech.clone()).seed(42).build()?;
        let a = sess.alloc(MemSpec::host("a").zeroed(n))?;
        let b = sess.alloc(MemSpec::host("b").zeroed(n))?;
        sess.compile_kernel("fill", FILL)?;
        sess.compile_kernel("scale", SCALE)?;
        sess.compile_kernel("total", TOTAL)?;

        // Stage 1 fills `a`, stage 2 reads `a` into `b`, stage 3 reduces
        // `b` — each on its own core quarter.
        let h1 = sess
            .launch_named("fill")?
            .args(&[ArgSpec::sharded_mut(a), ArgSpec::Float(1.0)])
            .mode(TransferMode::OnDemand)
            .cores((0..4).collect())
            .submit()?;
        if !wait_free {
            h1.wait(&mut sess)?;
        }
        let h2 = sess
            .launch_named("scale")?
            .args(&[ArgSpec::sharded(a), ArgSpec::sharded_mut(b)])
            .mode(TransferMode::OnDemand)
            .cores((4..8).collect())
            .submit()?;
        if !wait_free {
            h2.wait(&mut sess)?;
        }
        let h3 = sess
            .launch_named("total")?
            .arg(ArgSpec::sharded(b))
            .mode(TransferMode::OnDemand)
            .cores((8..12).collect())
            .submit()?;
        if wait_free {
            // The chain is in flight, ordered purely by data-flow edges.
            assert_eq!(h2.status(&sess), Some(LaunchStatus::Blocked));
            assert_eq!(h3.status(&sess), Some(LaunchStatus::Blocked));
            let qs = sess.queue_stats();
            println!(
                "submitted wait-free: {} blocked on edges, {} pending, {} active",
                qs.blocked, qs.pending, qs.active
            );
        }
        let r3 = h3.wait(&mut sess)?;
        if wait_free {
            h1.wait(&mut sess)?;
            h2.wait(&mut sess)?;
        }
        let sum: f64 = r3.reports.iter().map(|r| r.value.as_f64().unwrap()).sum();
        Ok((sum, sess.now(), r3.finished_at))
    };

    let (sum_b, now_b, fin_b) = run(false)?;
    let (sum_w, now_w, fin_w) = run(true)?;
    let mut t = Table::new(
        format!("fill → scale → total over {n} elements, {}", tech.name),
        &["variant", "chain finish (virtual ms)", "session clock (ms)", "Σ 2·(1+i)"],
    );
    t.row(&["blocking (wait per stage)".into(), ms(fin_b), ms(now_b), format!("{sum_b:.0}")]);
    t.row(&["wait-free (data-flow edges)".into(), ms(fin_w), ms(now_w), format!("{sum_w:.0}")]);
    print!("{}", t.render());
    assert_eq!((sum_b, now_b, fin_b), (sum_w, now_w, fin_w));
    println!("\nBit-identical: a dependent chain needs no waits — the edges are the schedule.");

    // An independent launch overlaps the chain instead of queueing.
    let mut sess = Session::builder(tech).seed(42).build()?;
    let a = sess.alloc(MemSpec::host("a").zeroed(n))?;
    let ones = vec![1.0f32; n];
    let c = sess.alloc(MemSpec::host("c").from(&ones))?;
    sess.compile_kernel("fill", FILL)?;
    sess.compile_kernel("total", TOTAL)?;
    let chain = sess
        .launch_named("fill")?
        .args(&[ArgSpec::sharded_mut(a), ArgSpec::Float(1.0)])
        .mode(TransferMode::OnDemand)
        .cores((0..8).collect())
        .submit()?;
    let indep = sess
        .launch_named("total")?
        .arg(ArgSpec::sharded(c))
        .mode(TransferMode::OnDemand)
        .cores((8..16).collect())
        .submit()?;
    let r_indep = indep.wait(&mut sess)?;
    chain.wait(&mut sess)?;
    assert_eq!(r_indep.launched_at, 0, "no conflict, no edge: starts immediately");
    println!(
        "independent launch started at virtual 0 while the chain ran — \
         disjoint data never queues."
    );
    Ok(())
}
